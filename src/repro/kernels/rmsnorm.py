"""Fused RMSNorm (TPU Pallas).

Row-tiled: each grid step normalizes a (block_rows, D) tile entirely in
VMEM — one HBM read + one write per element instead of the 3-4 passes an
unfused mean/rsqrt/scale chain costs. D rides the 128-lane minor dim; f32
accumulation regardless of input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed the TPU compiler-params struct from TPUCompilerParams to
# CompilerParams (jax 0.5): accept either so the kernels (and their
# interpret-mode tests) run on both sides of the rename.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

DEFAULT_BLOCK_ROWS = 256


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)               # (rows, D)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
            block_rows: int = DEFAULT_BLOCK_ROWS,
            interpret: bool = False) -> jax.Array:
    """x: (..., D); scale: (D,)."""
    orig_shape = x.shape
    D = x.shape[-1]
    rows = x.size // D
    xf = x.reshape(rows, D)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    n = xf.shape[0] // br

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xf, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
