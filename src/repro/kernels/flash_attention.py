"""Fused flash attention (TPU Pallas).

Online-softmax attention blocked for VMEM: grid (batch, q_heads, q_blocks,
k_blocks) with the k dimension "arbitrary" (sequential) so the running max /
denominator / accumulator live in VMEM scratch across k blocks. GQA is
expressed in the k/v BlockSpec index map (q head h reads kv head h // G).
Causal and sliding-window masks are applied with 2-D iota.

TPU adaptation notes (vs the CUDA flash-attention algorithm): no shared-memory
staging or warp shuffles — the MXU consumes (block_q x hd) @ (hd x block_k)
tiles directly from VMEM; block sizes default to 256/512, multiples of the
128-lane register shape. A production kernel would additionally skip
fully-masked k blocks with a lower-triangular grid; we mask instead (correct,
~2x compute overhead for causal) and record that in the perf log.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed the TPU compiler-params struct from TPUCompilerParams to
# CompilerParams (jax 0.5): accept either so the kernels (and their
# interpret-mode tests) run on both sides of the rename.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int, q_offset: int,
               block_q: int, block_k: int, n_k: int):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (block_q, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (block_k, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = (q_offset + qi * block_q
            + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0))
    kpos = (ki * block_k
            + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1))
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0:1]                       # (block_q, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)              # (block_q, 1)

    l_scr[:, 0:1] = l_scr[:, 0:1] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_scr[:, 0:1] = m_new
    v = v_ref[0, 0].astype(jnp.float32)          # (block_k, hd)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _finish():
        denom = jnp.maximum(l_scr[:, 0:1], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, q_offset: int = 0,
                    softmax_scale: Optional[float] = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Sk, KVH, hd) -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    n_q, n_k = Sq // block_q, Sk // block_k

    # (B, H, S, hd) layout for clean 2-D tiles
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, block_q=block_q, block_k=block_k, n_k=n_k)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
