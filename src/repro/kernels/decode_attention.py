"""Decode attention against a long KV cache (TPU Pallas).

Flash-decoding adapted to TPU: on GPU the cache is split across SMs with a
separate reduction kernel; on TPU we instead walk the cache blocks in the
"arbitrary" (sequential) grid dimension per (batch, kv-head), keeping the
online-softmax state for the G grouped q-heads in VMEM scratch. All q heads
of one kv group ride in a single (G x hd) tile so GQA costs one cache pass.
Valid-length masking reads a per-batch cache_len from a (B, 1) VMEM block.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed the TPU compiler-params struct from TPUCompilerParams to
# CompilerParams (jax 0.5): accept either so the kernels (and their
# interpret-mode tests) run on both sides of the rename.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

DEFAULT_BLOCK_S = 512
NEG_INF = -1e30


def _dec_kernel(q_ref, k_ref, v_ref, cl_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, block_s: int, n_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)              # (block_s, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    G = q.shape[0]
    kpos = (si * block_s
            + jax.lax.broadcasted_iota(jnp.int32, (G, block_s), 1))
    valid = kpos < cl_ref[0, 0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[:, 0:1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[:, 0:1] = l_scr[:, 0:1] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_scr[:, 0:1] = m_new
    v = v_ref[0, 0].astype(jnp.float32)              # (block_s, hd)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)

    @pl.when(si == n_s - 1)
    def _finish():
        denom = jnp.maximum(l_scr[:, 0:1], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len, *, softmax_scale: Optional[float] = None,
                     block_s: int = DEFAULT_BLOCK_S,
                     interpret: bool = False) -> jax.Array:
    """q: (B, 1, H, hd); caches: (B, S, KVH, hd); cache_len: (B,) or scalar."""
    B, _, H, hd = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    block_s = min(block_s, S)
    assert S % block_s == 0, (S, block_s)
    n_s = S // block_s

    cl = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (B,))
    cl2 = cl[:, None]                                 # (B, 1)

    qg = q.reshape(B, KVH, G, hd)
    kt = k_cache.transpose(0, 2, 1, 3)                # (B, KVH, S, hd)
    vt = v_cache.transpose(0, 2, 1, 3)

    kernel = functools.partial(_dec_kernel, scale=scale, block_s=block_s,
                               n_s=n_s)
    out = pl.pallas_call(
        kernel,
        grid=(B, KVH, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, block_s, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qg, kt, vt, cl2)
    return out.reshape(B, 1, H, hd)
