"""Batched serving engine: prefill + decode with continuous slot reuse.

Mirrors the DataServer design on the model side: a single entry point
(`generate`) over a fixed pool of decode slots; finished sequences free their
slot for the next request (continuous batching). Drives the same
prefill/decode_step artifacts the dry-run lowers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import AxisRules
from repro.models.lm import LM


@dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 = greedy
    cache_margin: int = 64


class ServeEngine:
    def __init__(self, model: LM, params, *,
                 rules: Optional[AxisRules] = None, seed: int = 0):
        self.model = model
        self.params = params
        self.rules = rules or AxisRules()
        self._rng = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            lambda p, t, f, cs: model.prefill(p, t, f, cache_size=cs,
                                              rules=self.rules),
            static_argnums=(3,))
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t, rules=self.rules))

    def generate(self, tokens: np.ndarray, frames=None, *,
                 cfg: Optional[ServeConfig] = None,
                 eos_id: Optional[int] = None) -> dict:
        """tokens: (B, S_prompt) int32 -> dict with sequences (B, S+new)."""
        cfg = cfg or ServeConfig()
        tokens = jnp.asarray(tokens, jnp.int32)
        B, S = tokens.shape
        cache_size = S + cfg.max_new_tokens + cfg.cache_margin
        logits, cache = self._prefill(self.params, tokens, frames,
                                      cache_size)
        out = [tokens]
        finished = jnp.zeros((B,), bool)
        steps = 0
        for i in range(cfg.max_new_tokens):
            nxt = self._sample(logits[:, -1], cfg)
            if eos_id is not None:
                finished = finished | (nxt[:, 0] == eos_id)
                nxt = jnp.where(finished[:, None], eos_id, nxt)
            out.append(nxt)
            steps += 1
            if eos_id is not None and bool(jnp.all(finished)):
                break
            logits, cache = self._decode(self.params, cache, nxt)
        seqs = jnp.concatenate(out, axis=1)
        return {"sequences": np.asarray(seqs), "decode_steps": steps,
                "prompt_len": S}

    def _sample(self, logits: jax.Array, cfg: ServeConfig) -> jax.Array:
        if cfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        self._rng, k = jax.random.split(self._rng)
        g = jax.random.categorical(k, logits / cfg.temperature, axis=-1)
        return g[:, None].astype(jnp.int32)
