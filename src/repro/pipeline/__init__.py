"""End-to-end online RL pipeline: event-driven rollouts → replay → learner.

The asynchronous actor/learner split the paper trains with (§5):

- actors — ``RolloutEngine`` episodes on the virtual-time event loop,
  streamed through ``TrajectoryWriter`` into the ``TrajectoryIngestor``;
- ingest — scenario outcomes become shaped rewards (``RewardSpec``),
  episodes are encoded and stamped with the behavior-policy version;
- learner — ``LearnerLoop`` packs token batches and runs real
  ``repro.train.ppo`` / ``repro.train.sft`` update steps, enforcing a
  staleness bound on off-policy experience;
- versions — ``PolicyVersionStore`` flows learner updates back to the
  actor side.
"""
from repro.pipeline.ingest import IngestConfig, TrajectoryIngestor, \
    encode_for_rl
from repro.pipeline.learner import LearnerConfig, LearnerLoop
from repro.pipeline.online import OnlinePipeline, PipelineConfig, \
    PipelineReport, build_fleet
from repro.pipeline.policy_store import PolicyVersionStore

__all__ = [
    "IngestConfig", "TrajectoryIngestor", "encode_for_rl",
    "LearnerConfig", "LearnerLoop",
    "OnlinePipeline", "PipelineConfig", "PipelineReport", "build_fleet",
    "PolicyVersionStore",
]
