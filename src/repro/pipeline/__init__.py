"""End-to-end online RL pipeline: event-driven rollouts → replay → learner.

The asynchronous actor/learner split the paper trains with (§5):

- actors — ``RolloutEngine`` episodes on the virtual-time event loop,
  streamed through ``TrajectoryWriter`` into the ``TrajectoryIngestor``;
- ingest — scenario outcomes become shaped rewards (``RewardSpec``),
  episodes are encoded, scored in micro-batches through one fused
  policy/value call, and stamped with the behavior-policy version;
- replay — a packed structure-of-arrays arena (``ReplayBuffer``) the
  learner samples as pre-stacked columns;
- learner — ``LearnerLoop`` packs token batches and runs real
  ``repro.train.ppo`` / ``repro.train.sft`` update steps, enforcing a
  staleness bound on off-policy experience;
- versions — ``PolicyVersionStore`` flows learner updates back to the
  actor side.

Set ``REPRO_DATAPLANE=scalar`` to run the per-sample parity oracle end
to end instead of the vectorized plane (see ``repro.pipeline.online``).
"""

from repro.pipeline.ingest import IngestConfig, TrajectoryIngestor, encode_for_rl
from repro.pipeline.learner import LearnerConfig, LearnerLoop
from repro.pipeline.online import (
    OnlinePipeline,
    PipelineConfig,
    PipelineReport,
    build_fleet,
    resolve_dataplane,
)
from repro.pipeline.policy_store import PolicyVersionStore

__all__ = [
    "IngestConfig",
    "TrajectoryIngestor",
    "encode_for_rl",
    "LearnerConfig",
    "LearnerLoop",
    "OnlinePipeline",
    "PipelineConfig",
    "PipelineReport",
    "build_fleet",
    "resolve_dataplane",
    "PolicyVersionStore",
]
