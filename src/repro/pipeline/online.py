"""Online pipeline orchestrator: the actor/learner split, end to end.

Wires the fleet (a live ``repro.cluster.Cluster`` — hosts, placement,
least-loaded routing, optional autoscaling — or a bare ``Gateway``), the
event-driven ``RolloutEngine``, the ``TrajectoryIngestor`` and the
``LearnerLoop`` into one closed loop: scenario episodes stream into the
replay buffer as reward-shaped samples, the learner runs real jitted
update steps, and each update publishes a new policy version back toward
the actors.

Two execution modes:

- ``run_interleaved`` — actor rounds and learner updates alternate.
  Fully deterministic per seed (the CI/benchmark mode): every round is an
  event-driven virtual-time run, drained before the learner takes its
  updates. Staleness still occurs — the buffer carries samples from
  earlier rounds, generated under policy versions the learner has since
  advanced past.
- ``run_concurrent`` — a real asynchronous split: the actor thread
  generates rounds continuously while the learner updates from the
  buffer as fast as experience arrives (the paper's semi-online mode).

The rollout→learner data plane has two implementations (see
``repro.pipeline.ingest``): the default ``dataplane="batched"`` plane
(micro-batched ingest flushes into a packed SoA replay arena, fused
learner batch assembly) and the per-sample ``dataplane="scalar"`` oracle
(batch-size-1 forwards into a dict-list buffer — the original path, kept
bit-exact). Set ``PipelineConfig.dataplane`` or the ``REPRO_DATAPLANE``
environment variable (which wins) to pick; both planes produce identical
samples, so this is a performance switch, not a semantics switch.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.cluster import AutoscalerConfig, Cluster, MachineSpec, default_specs
from repro.core.event_loop import EventLoop
from repro.core.gateway import Gateway
from repro.core.seeding import stable_seed
from repro.core.telemetry import Telemetry
from repro.data.replay_buffer import ReplayBuffer
from repro.pipeline.ingest import IngestConfig, TrajectoryIngestor
from repro.pipeline.learner import LearnerConfig, LearnerLoop
from repro.pipeline.policy_store import PolicyVersionStore
from repro.rollout.engine import RolloutConfig, RolloutEngine
from repro.rollout.scenarios import ScenarioRegistry, get_default_registry
from repro.rollout.writer import TrajectoryWriter


def build_fleet(
    n_replicas: int,
    *,
    runners_per_node: int = 32,
    seed: int = 0,
    specs: Optional[Sequence[MachineSpec]] = None,
    routing: str = "least_loaded",
    autoscaler: Optional[AutoscalerConfig] = None,
    telemetry: Optional[Telemetry] = None,
) -> Cluster:
    """A paper-shaped **live cluster** for the online pipeline.

    ``n_replicas`` runners are bin-packed onto hosts (default: enough
    Table-1 E5-2699 machines at one ``runners_per_node``-runner pool
    each), stochastic faults and autonomous recovery active, load-aware
    routing on, per-host contention tracked live, and — when an
    ``AutoscalerConfig`` is passed — elastic scaling armed.

    Migration note: this used to return ``(gateway, pools)`` built from
    a static pool list; it now returns a :class:`repro.cluster.Cluster`
    (``cluster.gateway`` / ``cluster.pools`` are the old pieces, and
    ``cluster.close()`` replaces the manual gateway/pool teardown)."""
    specs = specs or default_specs(n_replicas, runners_per_node=runners_per_node)
    return Cluster(
        specs,
        n_replicas,
        runners_per_node=runners_per_node,
        seed=seed,
        routing=routing,
        autoscaler=autoscaler,
        telemetry=telemetry,
    )


@dataclass
class PipelineConfig:
    rounds: int = 3  # actor rounds (interleaved mode)
    tasks_per_round: int = 16
    updates_per_round: int = 4
    max_inflight: int = 64
    writer_capacity: int = 256
    replay_capacity: int = 512
    seed: int = 0
    # optional virtual-time pacing: stop launching episodes in a round
    # once the round's virtual clock passes this (see RolloutConfig)
    virtual_deadline_s: Optional[float] = None
    # "batched" (micro-batched ingest + SoA arena + fused learner) or
    # "scalar" (per-sample oracle); REPRO_DATAPLANE overrides when set
    dataplane: str = "batched"


@dataclass
class PipelineReport:
    rounds: int = 0
    updates: int = 0
    versions_published: int = 0
    rollout_completed: int = 0
    rollout_failed: int = 0
    rollout_steps: int = 0
    reassignments: int = 0
    rollout_virtual_seconds: float = 0.0
    rollout_traj_per_min: float = 0.0  # virtual-time, fleet-projected
    rollout_wall_seconds: float = 0.0
    learner_steps_per_min: float = 0.0  # wall-clock update rate
    losses: list[float] = field(default_factory=list)
    loss_first_third: float = float("nan")
    loss_last_third: float = float("nan")
    loss_decreased: bool = False
    success_rate: float = 0.0
    success_by_family: dict = field(default_factory=dict)
    stale_dropped: int = 0
    stale_reweighted: int = 0
    staleness: dict = field(default_factory=dict)
    rollout_to_learner_s: dict = field(default_factory=dict)
    wall_seconds: float = 0.0
    dataplane: str = "batched"
    ingest_flushes: int = 0

    def to_dict(self) -> dict:
        d = dict(self.__dict__)
        d["losses"] = [round(float(x), 6) for x in self.losses]
        return d


def resolve_dataplane(cfg_value: str) -> str:
    """Pipeline data-plane selection: REPRO_DATAPLANE wins over config."""
    plane = os.environ.get("REPRO_DATAPLANE", "").strip() or cfg_value
    if plane not in ("batched", "scalar"):
        raise ValueError(f"unknown dataplane {plane!r}: use 'batched' or 'scalar'")
    return plane


class OnlinePipeline:
    """Actor/learner pipeline over one fleet, one trainer, one registry."""

    def __init__(
        self,
        fleet,
        n_replicas: Optional[int],
        trainer,
        *,
        registry: Optional[ScenarioRegistry] = None,
        pipe_cfg: Optional[PipelineConfig] = None,
        learner_cfg: Optional[LearnerConfig] = None,
        ingest_cfg: Optional[IngestConfig] = None,
        telemetry: Optional[Telemetry] = None,
    ):
        # ``fleet`` is a Cluster (the build_fleet product: the engine then
        # binds the autoscaler/contention control plane to each round's
        # loop) or a bare Gateway (legacy callers)
        self.cluster: Optional[Cluster] = None
        if not isinstance(fleet, Gateway):
            self.cluster = fleet
            self.gateway = fleet.gateway
            if n_replicas is None:
                n_replicas = fleet.n_replicas
        else:
            self.gateway = fleet
            assert n_replicas is not None, "n_replicas is required with a bare Gateway"
        self.n_replicas = n_replicas
        self.trainer = trainer
        self.registry = registry or get_default_registry()
        self.cfg = pipe_cfg or PipelineConfig()
        self.telemetry = telemetry or Telemetry()
        learner_cfg = learner_cfg or LearnerConfig()
        ingest_cfg = ingest_cfg or IngestConfig()

        self.dataplane = resolve_dataplane(self.cfg.dataplane)
        if self.dataplane == "scalar":
            # per-sample oracle end to end: batch-size-1 ingest forwards,
            # dict-list replay, dict-at-a-time learner assembly
            ingest_cfg = dataclasses.replace(ingest_cfg, micro_batch=1)
            learner_cfg = dataclasses.replace(learner_cfg, fused=False)
        backend = "soa" if self.dataplane == "batched" else "list"
        self.replay = ReplayBuffer(
            capacity=self.cfg.replay_capacity,
            seed=stable_seed(self.cfg.seed, "replay"),
            backend=backend,
            seq_len=ingest_cfg.seq_len if backend == "soa" else None,
        )
        self.store = PolicyVersionStore(trainer.params)
        self.ingestor = TrajectoryIngestor(
            self.replay,
            self.store,
            registry=self.registry,
            trainer=trainer if learner_cfg.algo == "ppo" else None,
            cfg=ingest_cfg,
            telemetry=self.telemetry,
        )
        self.writer = TrajectoryWriter(
            on_trajectory=self.ingestor, retain=False, capacity=self.cfg.writer_capacity
        )
        self.engine = RolloutEngine(
            self.cluster if self.cluster is not None else self.gateway,
            self.writer,
            registry=self.registry,
            config=RolloutConfig(
                max_inflight=self.cfg.max_inflight,
                virtual_deadline_s=self.cfg.virtual_deadline_s,
            ),
            telemetry=self.telemetry,
        )
        self.learner = LearnerLoop(
            trainer, self.replay, self.store, cfg=learner_cfg, telemetry=self.telemetry
        )
        self._rollout_totals = dict(
            completed=0,
            failed=0,
            steps=0,
            reassignments=0,
            virtual_seconds=0.0,
            wall_seconds=0.0,
        )
        self._rounds_run = 0

    # --------------------------------------------------------------- actors
    def _run_round(self, round_idx: int, abort: Optional[threading.Event] = None):
        if abort is not None and abort.is_set():
            # checked at round entry: run_event_driven re-arms the engine's
            # own stop flag, so a stop that landed between rounds would
            # otherwise be erased and the round would run to completion
            return
        tasks = self.registry.sample(
            self.cfg.tasks_per_round,
            seed=stable_seed(self.cfg.seed, "round", round_idx),
        )
        loop = EventLoop()
        # virtual-time flush deadline: a trickle of episodes can never
        # stall in the ingest pending batch for more than one tick
        self.ingestor.arm_virtual_flush(loop)
        report = self.engine.run_event_driven(tasks, loop=loop)
        tot = self._rollout_totals
        tot["completed"] += report.completed
        tot["failed"] += report.failed
        tot["steps"] += report.total_steps
        tot["reassignments"] += report.reassignments
        tot["virtual_seconds"] += report.virtual_seconds
        tot["wall_seconds"] += report.wall_seconds
        self._rounds_run += 1
        self.telemetry.gauge("actor_rounds", float(self._rounds_run))

    # ---------------------------------------------------------------- modes
    def run_interleaved(self) -> PipelineReport:
        """Alternate actor rounds and learner updates (deterministic)."""
        t0 = time.monotonic()
        for r in range(self.cfg.rounds):
            self._run_round(r)
            self.writer.drain()
            self.ingestor.flush()  # everything ingested reaches the learner
            for _ in range(self.cfg.updates_per_round):
                self.learner.step()
        return self._report(time.monotonic() - t0)

    def run_concurrent(
        self, total_updates: int, *, max_rounds: int = 64, poll_s: float = 0.02
    ) -> PipelineReport:
        """True async actor/learner split: the actor thread streams rounds
        while the learner updates from the buffer as experience lands."""
        t0 = time.monotonic()
        stop = threading.Event()

        def actor():
            for r in range(max_rounds):
                if stop.is_set():
                    break
                self._run_round(r, abort=stop)

        thread = threading.Thread(target=actor, name="pipeline-actor", daemon=True)
        thread.start()
        try:
            while self.learner.updates < total_updates:
                if not thread.is_alive():
                    # actor exhausted: wait out the writer's in-flight
                    # trajectories before concluding there is no more
                    # experience coming
                    self.writer.drain()
                    self.ingestor.flush()
                    if not self.learner.ready():
                        break
                if self.learner.ready():
                    self.learner.step()
                else:
                    # starved: give a partial ingest batch past its wall
                    # deadline a push instead of waiting out the trickle
                    self.ingestor.maybe_flush()
                    time.sleep(poll_s)
        finally:
            stop.set()
            self.engine.stop()
            thread.join(timeout=300.0)
            if thread.is_alive():
                # surface the wedge instead of reading rollout totals a
                # live actor thread is still mutating
                raise RuntimeError("pipeline actor thread failed to stop")
            self.writer.drain()
            self.ingestor.flush()
        return self._report(time.monotonic() - t0)

    def close(self) -> None:
        self.writer.close()

    # ------------------------------------------------------------ reporting
    def _report(self, wall: float) -> PipelineReport:
        snap = self.telemetry.snapshot()
        counters = snap["counters"]
        tot = self._rollout_totals
        trend = self.learner.loss_trend()
        families = {}
        for name, n in counters.items():
            if name.startswith("family_total:"):
                fam = name.split(":", 1)[1]
                ok = counters.get(f"family_success:{fam}", 0)
                families[fam] = {
                    "episodes": n,
                    "successes": ok,
                    "rate": ok / n if n else 0.0,
                }
        ingested = counters.get("ingested", 0)
        traj_per_min = 0.0
        if tot["completed"] and tot["virtual_seconds"] > 0:
            traj_per_min = (
                self.n_replicas * 60.0 * tot["completed"] / tot["virtual_seconds"]
            )
        return PipelineReport(
            rounds=self._rounds_run,
            updates=self.learner.updates,
            versions_published=self.store.publishes,
            rollout_completed=tot["completed"],
            rollout_failed=tot["failed"],
            rollout_steps=tot["steps"],
            reassignments=tot["reassignments"],
            rollout_virtual_seconds=tot["virtual_seconds"],
            rollout_traj_per_min=traj_per_min,
            rollout_wall_seconds=tot["wall_seconds"],
            learner_steps_per_min=self.learner.steps_per_min(),
            losses=list(self.learner.losses),
            loss_first_third=trend["first_third"],
            loss_last_third=trend["last_third"],
            loss_decreased=trend["decreased"],
            success_rate=(
                counters.get("ingest_success", 0) / ingested if ingested else 0.0
            ),
            success_by_family=families,
            stale_dropped=counters.get("stale_dropped", 0),
            stale_reweighted=counters.get("stale_reweighted", 0),
            staleness=snap["series"].get("staleness_versions", {"n": 0}),
            rollout_to_learner_s=snap["series"].get("rollout_to_learner_s", {"n": 0}),
            wall_seconds=wall,
            dataplane=self.dataplane,
            ingest_flushes=counters.get("ingest_flushes", 0),
        )
