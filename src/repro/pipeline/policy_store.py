"""Versioned policy publication: the learner publishes, actors pull.

The store is the single synchronization point between the learner (which
publishes a new parameter version after every update step) and the actor
side (which pulls the latest version when it stamps a finished episode's
behavior policy). Versions are how staleness is measured: an experience
generated under version ``v`` is ``current - v`` updates off-policy by the
time the learner consumes it.
"""

from __future__ import annotations

import threading
import time
from typing import Any


class PolicyVersionStore:
    """Thread-safe latest-wins parameter store with a version counter."""

    def __init__(self, params: Any = None):
        self._lock = threading.Lock()
        self._version = 0
        self._params = params
        self._published_wall = time.monotonic()
        self.publishes = 0

    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    def current(self) -> tuple[int, Any]:
        """(version, params) as one atomic read — actors stamp episodes
        with exactly the version whose parameters they used."""
        with self._lock:
            return self._version, self._params

    def publish(self, params: Any) -> int:
        """Install a new parameter version; returns its version number."""
        with self._lock:
            self._version += 1
            self._params = params
            self._published_wall = time.monotonic()
            self.publishes += 1
            return self._version

    def staleness(self, version: int) -> int:
        """How many updates behind the current policy ``version`` is."""
        with self._lock:
            return max(self._version - version, 0)

    def seconds_since_publish(self) -> float:
        with self._lock:
            return time.monotonic() - self._published_wall
