"""Learner loop: replay samples → packed token batches → real update steps.

Runs either PPO (``repro.train.ppo.PPOTrainer``) or SFT
(``repro.train.sft.SFTTrainer``) on samples produced by the
``TrajectoryIngestor``. Every update publishes a new policy version to the
``PolicyVersionStore``; every consumed sample is checked against the
staleness bound:

- within ``staleness_bound`` versions — used at full weight;
- beyond the bound with ``staleness_policy="drop"`` — evicted from the
  buffer and never trained on;
- beyond the bound with ``staleness_policy="reweight"`` — kept, but its
  advantages are discounted by ``staleness_decay**excess`` (an importance
  proxy for how far off-policy the behavior was), and evicted once the
  discount falls under ``min_weight``.

Both outcomes are counted in ``Telemetry`` (``stale_dropped`` /
``stale_reweighted``), alongside the rollout→learner latency of every
sample that reaches an update.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.telemetry import Telemetry
from repro.data.pipeline import pack_batches
from repro.data.replay_buffer import ReplayBuffer
from repro.pipeline.policy_store import PolicyVersionStore


@dataclass
class LearnerConfig:
    algo: str = "ppo"                   # "ppo" | "sft"
    batch_size: int = 8                 # trajectories per PPO update
    seq_len: int = 192
    staleness_bound: int = 8            # K: versions before off-policy acts
    staleness_policy: str = "reweight"  # "reweight" | "drop"
    staleness_decay: float = 0.8        # advantage discount per excess step
    min_weight: float = 0.05            # evict below this discount
    oversample: int = 2                 # sample this x batch_size, filter
    sft_pack_rows: int = 2              # packed rows per SFT batch
    sft_success_only: bool = True       # filtered behavior cloning


class LearnerLoop:
    """Drains the replay buffer into real PPO/SFT update steps."""

    def __init__(self, trainer, replay: ReplayBuffer,
                 store: PolicyVersionStore, *,
                 cfg: Optional[LearnerConfig] = None,
                 telemetry: Optional[Telemetry] = None):
        self.trainer = trainer
        self.replay = replay
        self.store = store
        self.cfg = cfg or LearnerConfig()
        self.telemetry = telemetry or Telemetry()
        assert self.cfg.algo in ("ppo", "sft"), self.cfg.algo
        assert self.cfg.staleness_policy in ("reweight", "drop"), \
            self.cfg.staleness_policy
        self.updates = 0
        self.losses: list[float] = []
        self._learn_wall = 0.0

    # ------------------------------------------------------------ staleness
    def _weight(self, version: int, sample_version: int) -> Optional[float]:
        """None -> unusable (drop); otherwise the advantage weight."""
        cfg = self.cfg
        excess = (version - sample_version) - cfg.staleness_bound
        if excess <= 0:
            return 1.0
        if cfg.staleness_policy == "drop":
            return None
        w = cfg.staleness_decay ** excess
        return w if w >= cfg.min_weight else None

    def _evict_stale(self, version: int) -> int:
        """Prune buffer items no future update could use."""
        dropped = self.replay.prune(
            lambda s: self._weight(version, s["version"]) is None)
        if dropped:
            self.telemetry.count("stale_dropped", dropped)
        return dropped

    # -------------------------------------------------------------- updates
    def ready(self) -> bool:
        need = (self.cfg.batch_size if self.cfg.algo == "ppo"
                else self.cfg.sft_pack_rows)
        return len(self.replay) >= need

    def step(self) -> Optional[dict]:
        """One learner update; returns metrics, or None when starved."""
        cfg = self.cfg
        t0 = time.monotonic()
        version = self.store.version
        self._evict_stale(version)
        pulled = self.replay.sample(cfg.batch_size * cfg.oversample)
        kept: list[dict] = []
        weights: list[float] = []
        for s in pulled:
            w = self._weight(version, s["version"])
            if w is None:
                continue
            if w < 1.0:
                self.telemetry.count("stale_reweighted")
            kept.append(s)
            weights.append(w)
            if len(kept) == cfg.batch_size:
                break
        if not kept:
            self.telemetry.count("learner_starved")
            return None
        # fixed batch shape keeps the jitted step on one compilation:
        # pad a starved batch by cycling the samples that did survive
        n_kept = len(kept)
        while len(kept) < cfg.batch_size:
            kept.append(kept[len(kept) % n_kept])
            weights.append(weights[len(weights) % n_kept])
            self.telemetry.count("learner_batch_padded")

        if cfg.algo == "ppo":
            metrics = self._ppo_update(kept, np.asarray(weights, np.float32))
        else:
            metrics = self._sft_update(kept)
        if metrics is None:
            return None

        new_version = self.store.publish(self.trainer.params)
        self.updates += 1
        self.losses.append(float(metrics["loss"]))
        self._learn_wall += time.monotonic() - t0

        now = time.monotonic()
        for s in kept:
            self.telemetry.observe("rollout_to_learner_s",
                                   now - s["ingest_wall"])
            self.telemetry.observe("staleness_versions",
                                   float(version - s["version"]))
        self.telemetry.count("learner_updates")
        self.telemetry.observe("learner_loss", float(metrics["loss"]))
        self.telemetry.gauge("policy_version", float(new_version))
        metrics["version"] = new_version
        return metrics

    def _ppo_update(self, kept: list[dict],
                    weights: np.ndarray) -> Optional[dict]:
        batch = self.trainer.make_batch(kept, seq_len=self.cfg.seq_len)
        batch["advantages"] = batch["advantages"] * weights[:, None]
        return self.trainer.update(batch)

    def _sft_update(self, kept: list[dict]) -> Optional[dict]:
        cfg = self.cfg
        chosen = kept
        if cfg.sft_success_only:
            successes = [s for s in kept if s.get("success")]
            if successes:
                chosen = successes
            else:
                self.telemetry.count("sft_fallback_unfiltered")
        encoded = [(s["tokens_full"], s["loss_mask_full"]) for s in chosen]
        # pack_batches only yields full batches; duplicate the stream until
        # it covers one packed batch of sft_pack_rows x seq_len tokens
        need = cfg.sft_pack_rows * (cfg.seq_len + 1)
        have = sum(len(t) for t, _ in encoded)
        if have == 0:
            self.telemetry.count("learner_starved")
            return None
        encoded = encoded * (need // max(have, 1) + 1)
        batch = next(pack_batches(encoded, batch=cfg.sft_pack_rows,
                                  seq_len=cfg.seq_len,
                                  seed=self.updates), None)
        if batch is None:
            self.telemetry.count("learner_starved")
            return None
        res = self.trainer.fit([batch], verbose=False)
        return {"loss": res.final_loss}

    # ----------------------------------------------------------- reporting
    def steps_per_min(self) -> float:
        if self._learn_wall <= 0:
            return 0.0
        return 60.0 * self.updates / self._learn_wall

    def loss_trend(self) -> dict:
        """Mean loss over the first vs last third of updates — the bench's
        'is it learning' signal, robust to per-step PPO noise."""
        n = len(self.losses)
        if n < 3:
            return {"first_third": float("nan"),
                    "last_third": float("nan"), "decreased": False}
        third = max(n // 3, 1)
        first = float(np.mean(self.losses[:third]))
        last = float(np.mean(self.losses[-third:]))
        return {"first_third": first, "last_third": last,
                "decreased": bool(last < first)}
