"""Learner loop: replay samples → packed token batches → real update steps.

Runs either PPO (``repro.train.ppo.PPOTrainer``) or SFT
(``repro.train.sft.SFTTrainer``) on samples produced by the
``TrajectoryIngestor``. Every update publishes a new policy version to the
``PolicyVersionStore``; every consumed sample is checked against the
staleness bound:

- within ``staleness_bound`` versions — used at full weight;
- beyond the bound with ``staleness_policy="drop"`` — evicted from the
  buffer and never trained on;
- beyond the bound with ``staleness_policy="reweight"`` — kept, but its
  advantages are discounted by ``staleness_decay**excess`` (an importance
  proxy for how far off-policy the behavior was), and evicted once the
  discount falls under ``min_weight``.

Both outcomes are counted in ``Telemetry`` (``stale_dropped`` /
``stale_reweighted``), alongside the rollout→learner latency of every
sample that reaches an update.

PPO updates run on the **fused data plane** by default: staleness
weights for the whole pulled batch are computed in one numpy pass,
samples arrive as pre-stacked columns (``ReplayBuffer.sample_columns``),
and the fixed-shape batch is assembled by ``make_batch_columns`` without
per-sample Python loops. The dict-at-a-time path remains as the parity
oracle (``fused=False``, or any trainer without ``make_batch_columns`` —
both paths draw the same sampler indices and produce bit-identical
batches). Starved batches are padded by cycling survivors to keep the
jitted step on one compilation, but padded slots are counted separately
(``learner_batch_padded``) and contribute nothing to the update or its
telemetry: their loss-mask rows and advantages are zeroed.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.telemetry import Telemetry
from repro.data.pipeline import pack_batches
from repro.data.replay_buffer import ReplayBuffer
from repro.pipeline.policy_store import PolicyVersionStore


@dataclass
class LearnerConfig:
    algo: str = "ppo"  # "ppo" | "sft"
    batch_size: int = 8  # trajectories per PPO update
    seq_len: int = 192
    staleness_bound: int = 8  # K: versions before off-policy acts
    staleness_policy: str = "reweight"  # "reweight" | "drop"
    staleness_decay: float = 0.8  # advantage discount per excess step
    min_weight: float = 0.05  # evict below this discount
    oversample: int = 2  # sample this x batch_size, filter
    sft_pack_rows: int = 2  # packed rows per SFT batch
    sft_success_only: bool = True  # filtered behavior cloning
    fused: bool = True  # vectorized PPO step (dict path = parity oracle)


class LearnerLoop:
    """Drains the replay buffer into real PPO/SFT update steps."""

    def __init__(
        self,
        trainer,
        replay: ReplayBuffer,
        store: PolicyVersionStore,
        *,
        cfg: Optional[LearnerConfig] = None,
        telemetry: Optional[Telemetry] = None,
    ):
        self.trainer = trainer
        self.replay = replay
        self.store = store
        self.cfg = cfg or LearnerConfig()
        self.telemetry = telemetry or Telemetry()
        assert self.cfg.algo in ("ppo", "sft"), self.cfg.algo
        assert self.cfg.staleness_policy in (
            "reweight",
            "drop",
        ), self.cfg.staleness_policy
        self.updates = 0
        self.losses: list[float] = []
        self._learn_wall = 0.0

    # ------------------------------------------------------------ staleness
    def _weight(self, version: int, sample_version: int) -> Optional[float]:
        """None -> unusable (drop); otherwise the advantage weight."""
        cfg = self.cfg
        excess = (version - sample_version) - cfg.staleness_bound
        if excess <= 0:
            return 1.0
        if cfg.staleness_policy == "drop":
            return None
        w = cfg.staleness_decay**excess
        return w if w >= cfg.min_weight else None

    def _weights_vec(self, version: int, sample_versions: np.ndarray) -> np.ndarray:
        """``_weight`` over a whole version column at once; unusable
        samples come back as NaN instead of None."""
        cfg = self.cfg
        excess = (int(version) - np.asarray(sample_versions, np.int64)) - int(
            cfg.staleness_bound
        )
        w = np.ones(len(excess), np.float64)
        stale = excess > 0
        if stale.any():
            # python pow per distinct excess, not np.power over the column:
            # they differ in the last ulp, and these weights scale
            # advantages — the planes must agree bit for bit
            for e in np.unique(excess[stale]):
                w[excess == e] = cfg.staleness_decay ** int(e)
        if cfg.staleness_policy == "drop":
            w[stale] = np.nan
        else:
            w[w < cfg.min_weight] = np.nan
        return w

    def _evict_stale(self, version: int) -> int:
        """Prune buffer items no future update could use — one vectorized
        pass over the buffer's version column."""
        dropped = self.replay.prune_where(
            lambda vers: np.isnan(self._weights_vec(version, vers))
        )
        if dropped:
            self.telemetry.count("stale_dropped", dropped)
        return dropped

    # -------------------------------------------------------------- updates
    def ready(self) -> bool:
        need = self.cfg.batch_size if self.cfg.algo == "ppo" else self.cfg.sft_pack_rows
        return len(self.replay) >= need

    def step(self) -> Optional[dict]:
        """One learner update; returns metrics, or None when starved."""
        cfg = self.cfg
        t0 = time.monotonic()
        version = self.store.version
        self._evict_stale(version)
        if (
            cfg.algo == "ppo"
            and cfg.fused
            and hasattr(self.trainer, "make_batch_columns")
        ):
            return self._step_ppo_fused(version, t0)
        return self._step_dicts(version, t0)

    # fused plane: columns in, one numpy staleness pass, no per-sample loops
    def _step_ppo_fused(self, version: int, t0: float) -> Optional[dict]:
        cfg = self.cfg
        cols = self.replay.sample_columns(
            cfg.batch_size * cfg.oversample, seq_len=cfg.seq_len
        )
        if cols is None:
            self.telemetry.count("learner_starved")
            return None
        w = self._weights_vec(version, cols["version"])
        usable = np.flatnonzero(~np.isnan(w))
        if usable.size == 0:
            self.telemetry.count("learner_starved")
            return None
        sel = usable[: cfg.batch_size]  # first usable, same as the dict scan
        n_kept = int(sel.size)
        n_reweighted = int((w[sel] < 1.0).sum())
        if n_reweighted:
            self.telemetry.count("stale_reweighted", n_reweighted)
        n_padded = cfg.batch_size - n_kept
        if n_padded:
            # fixed batch shape keeps the jitted step on one compilation:
            # cycle survivors into the padding slots (zeroed below)
            sel_full = np.concatenate([sel, sel[np.arange(n_padded) % n_kept]])
            self.telemetry.count("learner_batch_padded", n_padded)
        else:
            sel_full = sel
        batch = self.trainer.make_batch_columns(cols, sel_full, seq_len=cfg.seq_len)
        batch["advantages"] = batch["advantages"] * w[sel_full, None].astype(np.float32)
        if n_padded:
            # padded slots are shape filler: no loss-mask weight, no
            # gradient, no telemetry contribution
            batch["action_mask"][n_kept:] = 0.0
            batch["advantages"][n_kept:] = 0.0
        metrics = self.trainer.update(batch)
        if metrics is None:
            return None
        return self._finalize(
            metrics, t0, version, cols["ingest_wall"][sel], cols["version"][sel]
        )

    # oracle plane: dict-at-a-time scan (also serves SFT and any trainer
    # without column assembly)
    def _step_dicts(self, version: int, t0: float) -> Optional[dict]:
        cfg = self.cfg
        pulled = self.replay.sample(cfg.batch_size * cfg.oversample)
        kept: list[dict] = []
        weights: list[float] = []
        for s in pulled:
            w = self._weight(version, s["version"])
            if w is None:
                continue
            if w < 1.0:
                self.telemetry.count("stale_reweighted")
            kept.append(s)
            weights.append(w)
            if len(kept) == cfg.batch_size:
                break
        if not kept:
            self.telemetry.count("learner_starved")
            return None
        n_kept = len(kept)
        if cfg.algo == "ppo":
            while len(kept) < cfg.batch_size:
                kept.append(kept[len(kept) % n_kept])
                weights.append(weights[len(weights) % n_kept])
                self.telemetry.count("learner_batch_padded")
            metrics = self._ppo_update(kept, np.asarray(weights, np.float32), n_kept)
        else:
            metrics = self._sft_update(kept)
        if metrics is None:
            return None
        kept = kept[:n_kept]  # padded slots carry no telemetry
        walls = np.asarray([s["ingest_wall"] for s in kept], np.float64)
        versions = np.asarray([s["version"] for s in kept], np.int64)
        return self._finalize(metrics, t0, version, walls, versions)

    def _finalize(
        self,
        metrics: dict,
        t0: float,
        version: int,
        ingest_walls: np.ndarray,
        sample_versions: np.ndarray,
    ) -> dict:
        new_version = self.store.publish(self.trainer.params)
        self.updates += 1
        self.losses.append(float(metrics["loss"]))
        self._learn_wall += time.monotonic() - t0
        now = time.monotonic()
        for wall, sv in zip(ingest_walls, sample_versions):
            self.telemetry.observe("rollout_to_learner_s", now - float(wall))
            self.telemetry.observe("staleness_versions", float(version - int(sv)))
        self.telemetry.count("learner_updates")
        self.telemetry.observe("learner_loss", float(metrics["loss"]))
        self.telemetry.gauge("policy_version", float(new_version))
        metrics["version"] = new_version
        return metrics

    def _ppo_update(
        self, kept: list[dict], weights: np.ndarray, n_kept: int
    ) -> Optional[dict]:
        batch = self.trainer.make_batch(kept, seq_len=self.cfg.seq_len)
        batch["advantages"] = batch["advantages"] * weights[:, None]
        if n_kept < len(kept):
            batch["action_mask"][n_kept:] = 0.0
            batch["advantages"][n_kept:] = 0.0
        return self.trainer.update(batch)

    def _sft_update(self, kept: list[dict]) -> Optional[dict]:
        cfg = self.cfg
        chosen = kept
        if cfg.sft_success_only:
            successes = [s for s in kept if s.get("success")]
            if successes:
                chosen = successes
            else:
                self.telemetry.count("sft_fallback_unfiltered")
        encoded = [(s["tokens_full"], s["loss_mask_full"]) for s in chosen]
        # pack_batches only yields full batches; duplicate the stream until
        # it covers one packed batch of sft_pack_rows x seq_len tokens
        need = cfg.sft_pack_rows * (cfg.seq_len + 1)
        have = sum(len(t) for t, _ in encoded)
        if have == 0:
            self.telemetry.count("learner_starved")
            return None
        encoded = encoded * (need // max(have, 1) + 1)
        batch = next(
            pack_batches(
                encoded, batch=cfg.sft_pack_rows, seq_len=cfg.seq_len, seed=self.updates
            ),
            None,
        )
        if batch is None:
            self.telemetry.count("learner_starved")
            return None
        res = self.trainer.fit([batch], verbose=False)
        return {"loss": res.final_loss}

    # ----------------------------------------------------------- reporting
    def steps_per_min(self) -> float:
        if self._learn_wall <= 0:
            return 0.0
        return 60.0 * self.updates / self._learn_wall

    def loss_trend(self) -> dict:
        """Mean loss over the first vs last third of updates — the bench's
        'is it learning' signal, robust to per-step PPO noise."""
        n = len(self.losses)
        if n < 3:
            return {
                "first_third": float("nan"),
                "last_third": float("nan"),
                "decreased": False,
            }
        third = max(n // 3, 1)
        first = float(np.mean(self.losses[:third]))
        last = float(np.mean(self.losses[-third:]))
        return {
            "first_third": first,
            "last_third": last,
            "decreased": bool(last < first),
        }
