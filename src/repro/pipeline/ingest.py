"""Trajectory ingest: finished episodes → reward-shaped RL samples.

The ingestor is the ``TrajectoryWriter``'s ``on_trajectory`` consumer. For
every episode streamed out of the rollout engine it:

1. encodes the trajectory into token ids with a loss mask and *per-step
   boundaries* (``encode_for_rl``), so rewards can be credited to the
   token that completes each environment step;
2. shapes the scenario outcome into dense rewards via the task family's
   ``RewardSpec`` (success criteria + step penalties + efficiency bonus);
3. stamps the sample with the behavior-policy version pulled from the
   ``PolicyVersionStore`` and — for PPO — computes ``old_logp`` / value
   estimates under exactly those parameters (one jitted forward pass);
4. appends the sample to the ``ReplayBuffer`` the learner drains.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.telemetry import Telemetry
from repro.data.pipeline import Trajectory, encode_trajectory
from repro.data.replay_buffer import ReplayBuffer
from repro.data.tokenizer import ByteTokenizer
from repro.pipeline.policy_store import PolicyVersionStore
from repro.rollout.scenarios import ScenarioRegistry, get_default_registry


def encode_for_rl(traj: Trajectory, tok: ByteTokenizer, vocab_size: int,
                  obs_tokens: int = 4
                  ) -> tuple[np.ndarray, np.ndarray, list[int]]:
    """``data.pipeline.encode_trajectory`` with per-step boundaries: also
    returns, per environment step, the index of the token that completes
    that step's action — the position step rewards are credited to."""
    return encode_trajectory(traj, tok, vocab_size, obs_tokens,
                             return_step_ends=True)


@dataclass
class IngestConfig:
    seq_len: int = 192        # samples are truncated to this many tokens
    obs_tokens: int = 4       # screenshot placeholder tokens per step
    vocab_size: int = 264     # ByteTokenizer vocab (256 bytes + specials)


class TrajectoryIngestor:
    """``on_trajectory`` consumer turning episodes into learner samples."""

    def __init__(self, replay: ReplayBuffer, store: PolicyVersionStore, *,
                 registry: Optional[ScenarioRegistry] = None,
                 trainer=None,
                 cfg: Optional[IngestConfig] = None,
                 telemetry: Optional[Telemetry] = None):
        self.replay = replay
        self.store = store
        self.registry = registry or get_default_registry()
        self.trainer = trainer          # PPOTrainer; None -> SFT-only samples
        self.cfg = cfg or IngestConfig()
        self.telemetry = telemetry or Telemetry()
        self.tok = ByteTokenizer()
        self._pv = None
        if trainer is not None:
            import jax
            self._pv = jax.jit(trainer.policy_value)

    # ------------------------------------------------------------- consume
    def __call__(self, traj: Trajectory) -> None:
        cfg = self.cfg
        task = traj.task or {"task_id": traj.task_id,
                             "scenario": traj.task_id.rsplit("-", 1)[0]}
        scenario = self.registry.resolve(task)
        horizon = int(task.get("horizon", 15))
        n_steps = len(traj.steps)
        step_rewards = scenario.reward.step_rewards(traj.score, n_steps,
                                                    horizon)
        success = scenario.reward.success(traj.score)

        ids, mask, step_ends = encode_for_rl(traj, self.tok, cfg.vocab_size,
                                             cfg.obs_tokens)
        T = min(len(ids) - 1, cfg.seq_len)
        tokens = ids[:T]
        actions = ids[1:T + 1]
        action_mask = mask[1:T + 1]

        # credit each step's shaped reward to the action position that
        # completes it (position t predicts token t+1); rewards for steps
        # truncated away pile onto the final kept position so the terminal
        # signal survives truncation
        rewards = np.zeros(T, np.float32)
        for k, end in enumerate(step_ends):
            pos = min(end - 1, T - 1)
            rewards[pos] += step_rewards[k]

        version, params = self.store.current()
        sample = {
            "tokens": tokens, "actions": actions,
            "action_mask": action_mask, "rewards": rewards,
            "tokens_full": ids, "loss_mask_full": mask,
            "version": version, "ingest_wall": time.monotonic(),
            "task_id": traj.task_id, "scenario": scenario.name,
            "family": scenario.family, "score": traj.score,
            "success": success, "n_steps": n_steps,
            "episode_return": float(step_rewards.sum()),
        }
        if self._pv is not None and params is not None:
            sample["old_logp"], sample["values"] = self._behavior_eval(
                params, tokens, actions, T)
        self.replay.add(sample)

        self.telemetry.count("ingested")
        self.telemetry.count(f"family_total:{scenario.family}")
        if success:
            self.telemetry.count("ingest_success")
            self.telemetry.count(f"family_success:{scenario.family}")
        self.telemetry.observe("episode_return", sample["episode_return"])
        self.telemetry.observe("encoded_len", float(len(ids)))
        self.telemetry.gauge("replay_depth", float(len(self.replay)))

    # ------------------------------------------------------------ behavior
    def _behavior_eval(self, params, tokens: np.ndarray,
                       actions: np.ndarray, T: int
                       ) -> tuple[np.ndarray, np.ndarray]:
        """log pi_behavior(action) and value estimates under the params
        that were current when the episode finished (one fixed-shape jitted
        forward, so every trajectory reuses the same compilation)."""
        import jax
        import numpy as onp
        cfg = self.cfg
        padded = onp.zeros((1, cfg.seq_len), onp.int32)
        padded[0, :T] = tokens
        logits, values = self._pv(params, padded)
        logp_all = jax.nn.log_softmax(logits[0, :T].astype("float32"))
        logp = onp.asarray(logp_all)[onp.arange(T), actions]
        return (logp.astype(onp.float32),
                onp.asarray(values[0, :T], onp.float32))
