"""Trajectory ingest: finished episodes → reward-shaped RL samples.

The ingestor is the ``TrajectoryWriter``'s ``on_trajectory`` consumer. For
every episode streamed out of the rollout engine it:

1. encodes the trajectory into token ids with a loss mask and *per-step
   boundaries* (``encode_for_rl``), so rewards can be credited to the
   token that completes each environment step;
2. shapes the scenario outcome into dense rewards via the task family's
   ``RewardSpec`` (success criteria + step penalties + efficiency bonus);
3. stamps the sample with the behavior-policy version pulled from the
   ``PolicyVersionStore`` and — for PPO — computes ``old_logp`` / value
   estimates under exactly those parameters;
4. appends the sample to the ``ReplayBuffer`` the learner drains.

Step 3 runs on one of two data planes:

- **micro-batched** (default, ``micro_batch > 1``) — encoded samples
  accumulate in a pending group and flush through *one* fused jitted
  forward + log-softmax + gather per batch of ``micro_batch`` rows
  (fixed ``(B, seq_len)`` shape, so every flush reuses one compilation;
  a short flush pads the batch and discards the tail, unless it is below
  half occupancy — then the bit-identical single-row forward is cheaper
  than a mostly-padding batch). Pending
  groups are keyed by policy version — a version change flushes the old
  group first, so every row is scored under exactly the params it was
  stamped with. Partial batches never stall a trickle of episodes: they
  flush on a wall-clock deadline (``flush_wall_s``, checked on arrival
  and by ``maybe_flush``) and on a virtual-time tick when armed on the
  rollout event loop (``arm_virtual_flush``).
- **per-sample oracle** (``micro_batch <= 1``) — the original
  batch-size-1 path, kept as the bit-exact parity reference
  (``tests/test_dataplane.py`` asserts the planes agree to the bit).

The hot path is phase-timed (``encode_vs``, ``policy_value_wall``,
``replay_append_wall``) so a data-plane regression is attributable from
the telemetry summary alone.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.telemetry import Telemetry
from repro.data.pipeline import Trajectory, encode_trajectory, pad_stack
from repro.data.replay_buffer import ReplayBuffer
from repro.data.tokenizer import ByteTokenizer
from repro.envs.base import get_backend
from repro.pipeline.policy_store import PolicyVersionStore
from repro.rollout.scenarios import ScenarioRegistry, get_default_registry


def encode_for_rl(
    traj: Trajectory, tok: ByteTokenizer, vocab_size: int, obs_tokens: int = 4
) -> tuple[np.ndarray, np.ndarray, list[int]]:
    """``data.pipeline.encode_trajectory`` with per-step boundaries: also
    returns, per environment step, the index of the token that completes
    that step's action — the position step rewards are credited to."""
    return encode_trajectory(traj, tok, vocab_size, obs_tokens, return_step_ends=True)


@dataclass
class IngestConfig:
    seq_len: int = 192  # samples are truncated to this many tokens
    obs_tokens: int = 4  # screenshot placeholder tokens per step
    vocab_size: int = 264  # ByteTokenizer vocab (256 bytes + specials)
    micro_batch: int = 32  # rows per fused flush; <= 1 -> per-sample oracle
    flush_wall_s: float = 0.25  # wall deadline for a partial pending batch
    flush_virtual_s: float = 5.0  # virtual-time flush cadence (event loop)


class TrajectoryIngestor:
    """``on_trajectory`` consumer turning episodes into learner samples."""

    def __init__(
        self,
        replay: ReplayBuffer,
        store: PolicyVersionStore,
        *,
        registry: Optional[ScenarioRegistry] = None,
        trainer=None,
        cfg: Optional[IngestConfig] = None,
        telemetry: Optional[Telemetry] = None,
    ):
        self.replay = replay
        self.store = store
        self.registry = registry or get_default_registry()
        self.trainer = trainer  # PPOTrainer; None -> SFT-only samples
        self.cfg = cfg or IngestConfig()
        self.telemetry = telemetry or Telemetry()
        self.tok = ByteTokenizer()
        self._pv = None
        self._pv_batch = None
        # pending micro-batch state; guarded by _lock (the writer's
        # consumer thread appends while flush deadlines can fire from the
        # learner's poll loop or a virtual-time tick)
        self._lock = threading.Lock()
        self._pending: list[dict] = []
        self._pending_params = None
        self._pending_version = -1
        self._pending_since = 0.0
        if trainer is not None:
            # jitted closures are cached on the trainer: both take params
            # explicitly (pure in the trainer's weights), so every ingestor
            # sharing one trainer — e.g. per-region ingestors in a
            # federation — reuses one compilation instead of paying a
            # fresh trace per instance
            cache = getattr(trainer, "_ingest_jit_cache", None)
            if cache is not None:
                self._pv, self._pv_batch = cache
            else:
                import jax
                import jax.numpy as jnp

                self._pv = jax.jit(trainer.policy_value)

                def fused(params, tokens, actions):
                    logits, values = trainer.policy_value(params, tokens)
                    logp_all = jax.nn.log_softmax(logits.astype(jnp.float32))
                    logp = jnp.take_along_axis(
                        logp_all, actions[..., None], axis=-1)
                    return logp[..., 0], values

                self._pv_batch = jax.jit(fused)
                trainer._ingest_jit_cache = (self._pv, self._pv_batch)

    # ------------------------------------------------------------- consume
    def __call__(self, traj: Trajectory) -> None:
        cfg = self.cfg
        task = traj.task or {
            "task_id": traj.task_id,
            "scenario": traj.task_id.rsplit("-", 1)[0],
        }
        scenario = self.registry.resolve(task)
        horizon = int(task.get("horizon", 15))
        n_steps = len(traj.steps)
        step_rewards = scenario.reward.step_rewards(traj.score, n_steps, horizon)
        success = scenario.reward.success(traj.score)
        # cross-domain shaping: one learner drains a mixed stream, so each
        # backend's reward magnitude is normalized by its calibrated scale
        # before credit assignment. SimOS scales at exactly 1.0 and the
        # guard skips the multiply, keeping the legacy path bit-identical.
        scale = get_backend(scenario.backend).reward_scale
        if scale != 1.0:
            step_rewards = step_rewards * np.float32(scale)

        with self.telemetry.timer("encode_vs"):
            ids, mask, step_ends = encode_for_rl(
                traj, self.tok, cfg.vocab_size, cfg.obs_tokens
            )
        T = min(len(ids) - 1, cfg.seq_len)
        tokens = ids[:T]
        actions = ids[1 : T + 1]
        action_mask = mask[1 : T + 1]

        # credit each step's shaped reward to the action position that
        # completes it (position t predicts token t+1); rewards for steps
        # truncated away pile onto the final kept position so the terminal
        # signal survives truncation
        rewards = np.zeros(T, np.float32)
        for k, end in enumerate(step_ends):
            pos = min(end - 1, T - 1)
            rewards[pos] += step_rewards[k]

        version, params = self.store.current()
        sample = {
            "tokens": tokens,
            "actions": actions,
            "action_mask": action_mask,
            "rewards": rewards,
            "tokens_full": ids,
            "loss_mask_full": mask,
            "version": version,
            "ingest_wall": time.monotonic(),
            "task_id": traj.task_id,
            "scenario": scenario.name,
            "family": scenario.family,
            "backend": scenario.backend,
            "score": traj.score,
            "success": success,
            "n_steps": n_steps,
            "episode_return": float(step_rewards.sum()),
        }

        if self._pv is None or params is None:
            with self.telemetry.timer("replay_append_wall"):
                self.replay.add(sample)
        elif cfg.micro_batch <= 1:
            # per-sample oracle: one batch-size-1 jitted forward per episode
            with self.telemetry.timer("policy_value_wall"):
                sample["old_logp"], sample["values"] = self._behavior_eval(
                    params, tokens, actions, T
                )
            with self.telemetry.timer("replay_append_wall"):
                self.replay.add(sample)
        else:
            with self._lock:
                if self._pending and version != self._pending_version:
                    # new policy version: score the old group under its
                    # own params before the first row of the new one lands
                    self._flush_locked()
                if not self._pending:
                    self._pending_params = params
                    self._pending_version = version
                    self._pending_since = time.monotonic()
                self._pending.append(sample)
                if len(self._pending) >= cfg.micro_batch or (
                    time.monotonic() - self._pending_since >= cfg.flush_wall_s
                ):
                    self._flush_locked()

        self.telemetry.count("ingested")
        self.telemetry.count(f"family_total:{scenario.family}")
        self.telemetry.count(f"backend_total:{scenario.backend}")
        if success:
            self.telemetry.count("ingest_success")
            self.telemetry.count(f"family_success:{scenario.family}")
            self.telemetry.count(f"backend_success:{scenario.backend}")
        self.telemetry.observe("episode_return", sample["episode_return"])
        self.telemetry.observe("encoded_len", float(len(ids)))
        self.telemetry.gauge("replay_depth", float(len(self.replay)))

    # ------------------------------------------------------------- flushing
    @property
    def pending_rows(self) -> int:
        with self._lock:
            return len(self._pending)

    def flush(self) -> int:
        """Force-flush the pending micro-batch; returns rows flushed."""
        return self.maybe_flush(force=True)

    def maybe_flush(self, *, force: bool = False) -> int:
        """Flush the pending group if forced or past the wall deadline."""
        if self._pv_batch is None or self.cfg.micro_batch <= 1:
            return 0
        with self._lock:
            if not self._pending:
                return 0
            overdue = time.monotonic() - self._pending_since >= self.cfg.flush_wall_s
            if force or overdue:
                return self._flush_locked()
            return 0

    def arm_virtual_flush(self, loop) -> None:
        """Schedule a recurring virtual-time flush tick on an event loop
        (daemon events: the tick never keeps the round alive). Each tick
        bounds pending latency to one ``flush_virtual_s`` period, so a
        trickle of episodes never stalls behind a partial batch."""
        period = self.cfg.flush_virtual_s
        if self._pv_batch is None or self.cfg.micro_batch <= 1:
            return
        if not np.isfinite(period) or period <= 0:
            return  # virtual-deadline flushing disabled

        def tick() -> None:
            self.maybe_flush(force=True)
            loop.call_later(period, tick, daemon=True)

        loop.call_later(period, tick, daemon=True)

    def _flush_locked(self) -> int:
        """Score and append the pending group (lock held). Groups at least
        half full go through one fused jitted call at the fixed
        ``(micro_batch, seq_len)`` shape (short groups pad with zero rows
        whose outputs are dropped); trickle groups below half occupancy go
        through the single-row forward instead — a mostly-padding fused
        call would spend more compute on discarded rows than on real ones.
        Both routes are bit-identical (the parity suite pins this), so the
        split is purely a cost model."""
        pending = self._pending
        r = len(pending)
        if r == 0:
            return 0
        cfg = self.cfg
        tokens = pad_stack(
            [s["tokens"] for s in pending], width=cfg.seq_len, dtype=np.int32
        )
        actions = pad_stack(
            [s["actions"] for s in pending], width=cfg.seq_len, dtype=np.int32
        )
        with self.telemetry.timer("policy_value_wall"):
            if 2 * r >= cfg.micro_batch:
                B = max(cfg.micro_batch, r)
                if r < B:  # fixed flush shape -> single compilation
                    pad = np.zeros((B - r, cfg.seq_len), np.int32)
                    tok_in = np.concatenate([tokens, pad])
                    act_in = np.concatenate([actions, pad])
                else:
                    tok_in, act_in = tokens, actions
                logp, values = self._pv_batch(self._pending_params, tok_in, act_in)
                logp = np.asarray(logp)[:r]
                values = np.asarray(values)[:r]
            else:
                logp = np.zeros((r, cfg.seq_len), np.float32)
                values = np.zeros((r, cfg.seq_len), np.float32)
                for i, s in enumerate(pending):
                    t = len(s["tokens"])
                    logp[i, :t], values[i, :t] = self._behavior_eval(
                        self._pending_params, s["tokens"], s["actions"], t
                    )
        lengths = np.asarray([len(s["tokens"]) for s in pending], np.int64)
        live = np.arange(cfg.seq_len)[None, :] < lengths[:, None]
        columns = {
            "tokens": tokens[:r],
            "actions": actions[:r],
            "action_mask": pad_stack(
                [s["action_mask"] for s in pending], width=cfg.seq_len, dtype=np.float32
            ),
            "rewards": pad_stack(
                [s["rewards"] for s in pending], width=cfg.seq_len, dtype=np.float32
            ),
            # padded positions carry log-softmax of pad logits: zero them so
            # arena rows match the oracle's [:T]-sliced outputs exactly
            "old_logp": np.where(live, logp, 0.0).astype(np.float32),
            "values": np.where(live, values, 0.0).astype(np.float32),
            "version": np.full(r, self._pending_version, np.int64),
            "ingest_wall": np.asarray([s["ingest_wall"] for s in pending], np.float64),
        }
        metas = [
            {k: v for k, v in s.items() if k not in _COLUMN_KEYS} for s in pending
        ]
        with self.telemetry.timer("replay_append_wall"):
            self.replay.extend_columns(columns, lengths, metas)
        self.telemetry.count("ingest_flushes")
        self.telemetry.observe("ingest_flush_rows", float(r))
        self.telemetry.gauge("replay_depth", float(len(self.replay)))
        self._pending = []
        self._pending_params = None
        return r

    # ------------------------------------------------------------ behavior
    def _behavior_eval(
        self, params, tokens: np.ndarray, actions: np.ndarray, T: int
    ) -> tuple[np.ndarray, np.ndarray]:
        """log pi_behavior(action) and value estimates under the params
        that were current when the episode finished (one fixed-shape jitted
        forward, so every trajectory reuses the same compilation)."""
        import jax
        import numpy as onp

        cfg = self.cfg
        padded = onp.zeros((1, cfg.seq_len), onp.int32)
        padded[0, :T] = tokens
        logits, values = self._pv(params, padded)
        logp_all = jax.nn.log_softmax(logits[0, :T].astype("float32"))
        logp = onp.asarray(logp_all)[onp.arange(T), actions]
        return (logp.astype(onp.float32), onp.asarray(values[0, :T], onp.float32))


# sample keys that live in the flush columns; everything else is meta
_COLUMN_KEYS = frozenset(
    {"tokens", "actions", "action_mask", "rewards", "version", "ingest_wall"}
)
