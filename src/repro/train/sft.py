"""Supervised finetuning on demonstration trajectories (§4.2 stage 2)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import AxisRules
from repro.models.lm import LM
from repro.train.optimizer import Optimizer, OptimizerConfig
from repro.train.train_step import TrainConfig, donate_argnums, make_train_step


@dataclass
class SFTResult:
    losses: list
    final_loss: float
    steps: int


class SFTTrainer:
    def __init__(self, model: LM, *, opt_cfg: Optional[OptimizerConfig] = None,
                 train_cfg: Optional[TrainConfig] = None,
                 rules: Optional[AxisRules] = None, seed: int = 0):
        self.model = model
        self.opt = Optimizer(opt_cfg or OptimizerConfig(lr=1e-3,
                                                        warmup_steps=20))
        self.rules = rules or AxisRules()
        tc = train_cfg or TrainConfig(microbatches=1, remat=None)
        self._step = jax.jit(make_train_step(model, self.opt, self.rules, tc),
                             donate_argnums=donate_argnums(tc))
        self.params = model.init(jax.random.PRNGKey(seed))
        self.opt_state = self.opt.init(self.params)

    def fit(self, batches: Iterable[dict], *, log_every: int = 20,
            verbose: bool = True) -> SFTResult:
        losses = []
        step = 0
        for batch in batches:
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt_state, metrics = self._step(
                self.params, self.opt_state, batch)
            step += 1
            loss = float(metrics["loss"])
            losses.append(loss)
            if verbose and step % log_every == 0:
                print(f"  sft step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e}")
        return SFTResult(losses, losses[-1] if losses else float("nan"), step)
