"""Training step factory: microbatched gradient accumulation (bounds
activation memory at 300-400B scale), remat policies, optional int8
cross-pod gradient compression with error feedback."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.distributed.sharding import AxisRules
from repro.models.lm import LM
from repro.train.optimizer import Optimizer


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: Optional[str] = "full"        # None | full | dots | dots_no_batch
    unroll_microbatches: bool = False    # dry-run: unroll for HLO accounting
    accum_dtype: str = "float32"         # bfloat16 for the 300-400B configs
    grad_compression: Optional[str] = None   # None | "int8_ef"
    loss_dtype: str = "float32"
    # donate (params, opt_state) into the jitted step so every update
    # reuses the previous step's device buffers instead of allocating a
    # fresh copy of the model state. Opt-in: donation invalidates any
    # externally-held reference to the pre-step params (checkpoints,
    # policy stores), so only enable it for an isolated training loop.
    donate: bool = False


def donate_argnums(cfg: "TrainConfig") -> tuple[int, ...]:
    """jit donate_argnums for a train_step(params, opt_state, batch)."""
    return (0, 1) if cfg.donate else ()


def _split_microbatches(batch: dict, n: int) -> dict:
    def r(x):
        if x is None:
            return None
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape((n, b // n) + x.shape[1:])
    return {k: r(v) for k, v in batch.items()}


def make_loss_fn(model: LM, rules: AxisRules, cfg: TrainConfig):
    def loss_fn(params, batch):
        return model.loss(params, batch, rules=rules, remat=cfg.remat)
    return loss_fn


def make_grad_fn(model: LM, rules: AxisRules, cfg: TrainConfig,
                 param_pspecs=None):
    """Returns grad_fn(params, batch) -> (loss, grads), microbatched.

    `param_pspecs` (PartitionSpec tree matching params) pins per-microbatch
    gradients to the FSDP parameter layout, so GSPMD reduce-scatters each
    microbatch's gradients into the shard owner (ZeRO-2) instead of
    all-reducing replicated full-size gradients."""
    loss_fn = make_loss_fn(model, rules, cfg)
    vg = jax.value_and_grad(loss_fn)

    def constrain(grads):
        if param_pspecs is None or rules.mesh is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(
                g, NamedSharding(rules.mesh, s)), grads, param_pspecs)

    if cfg.microbatches <= 1:
        def single(params, batch):
            loss, grads = vg(params, batch)
            return loss, constrain(grads)
        return single

    n = cfg.microbatches

    def grad_fn(params, batch):
        mbs = _split_microbatches(batch, n)

        acc_dt = jnp.dtype(cfg.accum_dtype)

        def body(carry, mb):
            loss_acc, grad_acc = carry
            mb = {k: v for k, v in mb.items() if v is not None}
            loss, grads = vg(params, mb)
            grads = constrain(grads)
            grad_acc = jax.tree.map(
                lambda a, g: a + g.astype(acc_dt), grad_acc, grads)
            grad_acc = constrain(grad_acc)
            return (loss_acc + loss, grad_acc), None

        zeros = constrain(jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dt), params))
        (loss_sum, grad_sum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), mbs,
            unroll=n if cfg.unroll_microbatches else 1)
        inv = 1.0 / n
        return loss_sum * inv, jax.tree.map(
            lambda g: (g * inv), grad_sum)

    return grad_fn


def make_train_step(model: LM, optimizer: Optimizer, rules: AxisRules,
                    cfg: Optional[TrainConfig] = None,
                    compress_fn: Optional[Callable] = None,
                    param_pspecs=None):
    """Build train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). Gradient cross-replica reduction is inserted by GSPMD from the
    batch sharding; `compress_fn` (e.g. int8+error-feedback, see
    repro.distributed.collectives) post-processes gradients before the
    optimizer."""
    cfg = cfg or TrainConfig()
    grad_fn = make_grad_fn(model, rules, cfg, param_pspecs=param_pspecs)

    def train_step(params, opt_state, batch):
        loss, grads = grad_fn(params, batch)
        if compress_fn is not None:
            grads, opt_state = compress_fn(grads, opt_state)
        params, opt_state, info = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, **info}
        return params, opt_state, metrics

    return train_step
