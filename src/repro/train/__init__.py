from repro.train.optimizer import Optimizer, OptimizerConfig
from repro.train.train_step import TrainConfig, make_train_step, make_grad_fn
from repro.train.sft import SFTTrainer
