"""Optimizers with sharded state: AdamW (configurable moment dtype — bf16
moments for the 300-400B configs) and Adafactor (factored second moments).
State trees mirror the parameter tree, so the same logical-axis sharding
rules apply to optimizer state; no external deps (optax is not available).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adafactor | sgd
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    moment_dtype: str = "float32"  # bfloat16 for >=100B models
    warmup_steps: int = 100
    decay_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


class Optimizer:
    """Functional optimizer: init(params) -> state; update(...)."""

    def __init__(self, cfg: OptimizerConfig):
        self.cfg = cfg

    # --------------------------------------------------------------- init
    def init(self, params):
        c = self.cfg
        mdt = jnp.dtype(c.moment_dtype)
        if c.name == "sgd":
            return {"step": jnp.zeros((), jnp.int32)}
        if c.name == "adamw":
            zeros = lambda p: jnp.zeros(p.shape, mdt)
            return {
                "step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
            }
        if c.name == "adafactor":
            def vrow(p):
                if p.ndim < 2:
                    return jnp.zeros(p.shape, jnp.float32)
                return jnp.zeros(p.shape[:-1], jnp.float32)

            def vcol(p):
                if p.ndim < 2:
                    return jnp.zeros((), jnp.float32)
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)

            return {
                "step": jnp.zeros((), jnp.int32),
                "vr": jax.tree.map(vrow, params),
                "vc": jax.tree.map(vcol, params),
            }
        raise ValueError(self.cfg.name)

    def state_logical_axes(self, param_axes):
        """Sharding axes for optimizer state (mirror the params)."""
        c = self.cfg
        if c.name == "sgd":
            return {"step": ()}
        if c.name == "adamw":
            return {"step": (), "m": param_axes, "v": param_axes}
        drop_last = lambda ax: ax[:-1] if len(ax) >= 2 else ax
        drop_2nd = lambda ax: (ax[:-2] + ax[-1:]) if len(ax) >= 2 else ()
        mapt = lambda f: jax.tree.map(f, param_axes,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return {"step": (), "vr": mapt(drop_last), "vc": mapt(drop_2nd)}

    # ------------------------------------------------------------- update
    def update(self, grads, state, params):
        c = self.cfg
        step = state["step"] + 1
        lr = schedule(c, step)
        if c.grad_clip:
            grads, gnorm = clip_by_global_norm(grads, c.grad_clip)
        else:
            gnorm = global_norm(grads)

        if c.name == "sgd":
            new_params = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_params, {"step": step}, {"lr": lr, "grad_norm": gnorm}

        if c.name == "adamw":
            bc1 = 1 - c.b1 ** step.astype(jnp.float32)
            bc2 = 1 - c.b2 ** step.astype(jnp.float32)

            def upd(p, g, m, v):
                gf = g.astype(jnp.float32)
                mf = c.b1 * m.astype(jnp.float32) + (1 - c.b1) * gf
                vf = c.b2 * v.astype(jnp.float32) + (1 - c.b2) * gf * gf
                upd_ = (mf / bc1) / (jnp.sqrt(vf / bc2) + c.eps)
                pf = p.astype(jnp.float32)
                pf = pf - lr * (upd_ + c.weight_decay * pf)
                return pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

            flat_p, tdef = jax.tree.flatten(params)
            flat_g = jax.tree.leaves(grads)
            flat_m = jax.tree.leaves(state["m"])
            flat_v = jax.tree.leaves(state["v"])
            out = [upd(p, g, m, v) for p, g, m, v
                   in zip(flat_p, flat_g, flat_m, flat_v)]
            new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
            new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
            new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
            return (new_params, {"step": step, "m": new_m, "v": new_v},
                    {"lr": lr, "grad_norm": gnorm})

        if c.name == "adafactor":
            def upd(p, g, vr, vc):
                gf = g.astype(jnp.float32)
                g2 = gf * gf + 1e-30
                if p.ndim < 2:
                    nvr = c.b2 * vr + (1 - c.b2) * g2
                    upd_ = gf / (jnp.sqrt(nvr) + c.eps)
                    nvc = vc
                else:
                    nvr = c.b2 * vr + (1 - c.b2) * jnp.mean(g2, axis=-1)
                    nvc = c.b2 * vc + (1 - c.b2) * jnp.mean(g2, axis=-2)
                    r = nvr / jnp.maximum(
                        jnp.mean(nvr, axis=-1, keepdims=True), 1e-30)
                    denom = jnp.sqrt(r[..., None] * nvc[..., None, :]) + c.eps
                    upd_ = gf / denom
                pf = p.astype(jnp.float32) - lr * (
                    upd_ + c.weight_decay * p.astype(jnp.float32))
                return pf.astype(p.dtype), nvr, nvc

            flat_p, tdef = jax.tree.flatten(params)
            out = [upd(p, g, vr, vc) for p, g, vr, vc in zip(
                flat_p, jax.tree.leaves(grads),
                jax.tree.leaves(state["vr"]), jax.tree.leaves(state["vc"]))]
            new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
            new_vr = jax.tree.unflatten(tdef, [o[1] for o in out])
            new_vc = jax.tree.unflatten(tdef, [o[2] for o in out])
            return (new_params, {"step": step, "vr": new_vr, "vc": new_vc},
                    {"lr": lr, "grad_norm": gnorm})

        raise ValueError(c.name)
