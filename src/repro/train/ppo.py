"""PPO for the semi-online asynchronous RL stage (§4.2 stage 3).

The policy is the LM (actions are token sequences); a linear value head reads
the final hidden state. Rollouts arrive through the DataServer's async
batched interface into the replay buffer; the learner samples independently
— rollouts and updates are decoupled exactly as in the paper.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import AxisRules
from repro.models.lm import LM
from repro.models.param import Spec, init_params
from repro.train.optimizer import Optimizer, OptimizerConfig

# step-arg donation sets per PPOConfig.donate: donating the optimizer
# state is always safe (nothing outside the trainer holds it); donating
# params frees the previous step's buffers too but invalidates any
# externally-held reference — e.g. a PolicyVersionStore snapshot actors
# are still scoring with — so "all" is opt-in for isolated learners.
_DONATE_ARGNUMS = {"none": (), "opt_state": (1,), "all": (0, 1)}


@dataclass(frozen=True)
class PPOConfig:
    clip_eps: float = 0.2
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    gamma: float = 0.99
    gae_lambda: float = 0.95
    lr: float = 1e-6  # paper: 1e-6 Adam
    batch_size: int = 64  # paper: 64
    epochs_per_batch: int = 1
    donate: str = "opt_state"  # "none" | "opt_state" | "all"


def compute_gae(
    rewards: np.ndarray, values: np.ndarray, gamma: float, lam: float
) -> tuple[np.ndarray, np.ndarray]:
    """rewards/values: (T,). Returns (advantages, returns)."""
    T = len(rewards)
    adv = np.zeros(T, np.float32)
    last = 0.0
    for t in reversed(range(T)):
        next_v = values[t + 1] if t + 1 < T else 0.0
        delta = rewards[t] + gamma * next_v - values[t]
        last = delta + gamma * lam * last
        adv[t] = last
    return adv, adv + values[:T]


# the accumulation dtype the scalar loop promotes to: float32 under NEP 50
# (numpy >= 2), float64 under legacy promotion — matching it keeps the
# batched recursion bit-identical to compute_gae on either numpy
_GAE_ACC_DT = (np.float32(0) + 0.0).dtype


def compute_gae_batch(
    rewards: np.ndarray, values: np.ndarray, gamma: float, lam: float
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized GAE over ``(B, S)`` row blocks — one backward sweep for
    the whole batch instead of a Python loop per sample.

    Rows zero-padded beyond their true length yield zero advantage/return
    in the padding (delta and the recursion both collapse to 0 there), and
    the live prefix is bit-identical per element to running ``compute_gae``
    on the unpadded row."""
    r = rewards.astype(_GAE_ACC_DT)
    v = values.astype(_GAE_ACC_DT)
    B, S = r.shape
    adv = np.zeros((B, S), np.float32)
    zero = np.zeros(B, _GAE_ACC_DT)
    last = zero
    for t in range(S - 1, -1, -1):
        next_v = v[:, t + 1] if t + 1 < S else zero
        delta = r[:, t] + gamma * next_v - v[:, t]
        last = delta + gamma * lam * last
        adv[:, t] = last
    return adv, adv + values.astype(np.float32)


class PPOTrainer:
    """Clipped-objective PPO over (tokens, action_mask, old_logp, adv, ret)."""

    def __init__(
        self,
        model: LM,
        params,
        *,
        cfg: Optional[PPOConfig] = None,
        rules: Optional[AxisRules] = None,
        seed: int = 0,
    ):
        self.model = model
        self.cfg = cfg or PPOConfig()
        self.rules = rules or AxisRules()
        assert self.cfg.donate in _DONATE_ARGNUMS, self.cfg.donate
        vh_spec = {
            "w": Spec((model.cfg.d_model, 1), ("embed", None), "scaled", "float32")
        }
        self.params = {
            "lm": params,
            "value_head": init_params(jax.random.PRNGKey(seed + 1), vh_spec, "float32"),
        }
        self.opt = Optimizer(
            OptimizerConfig(name="adamw", lr=self.cfg.lr, warmup_steps=0, grad_clip=1.0)
        )
        self.opt_state = self.opt.init(self.params)
        self._step = jax.jit(
            self._make_step(), donate_argnums=_DONATE_ARGNUMS[self.cfg.donate]
        )

    def policy_value(self, params, tokens):
        logits, _, hidden = self.model.forward(
            params["lm"], tokens, rules=self.rules, return_hidden=True
        )
        values = (hidden.astype(jnp.float32) @ params["value_head"]["w"])[..., 0]
        return logits.astype(jnp.float32), values

    def _make_step(self):
        cfg = self.cfg

        def loss_fn(params, batch):
            logits, values = self.policy_value(params, batch["tokens"])
            logp_all = jax.nn.log_softmax(logits, axis=-1)
            logp = jnp.take_along_axis(logp_all, batch["actions"][..., None], axis=-1)[
                ..., 0
            ]
            mask = batch["action_mask"]
            ratio = jnp.exp(logp - batch["old_logp"])
            adv = batch["advantages"]
            unclipped = ratio * adv
            clipped = jnp.clip(ratio, 1 - cfg.clip_eps, 1 + cfg.clip_eps) * adv
            pg = -jnp.sum(jnp.minimum(unclipped, clipped) * mask)
            v_loss = jnp.sum(jnp.square(values - batch["returns"]) * mask)
            ent = -jnp.sum(jnp.sum(jnp.exp(logp_all) * logp_all, -1) * mask)
            denom = jnp.maximum(jnp.sum(mask), 1.0)
            total = (pg + cfg.value_coef * v_loss - cfg.entropy_coef * ent) / denom
            return total, {"pg": pg / denom, "v": v_loss / denom, "entropy": ent / denom}

        def step(params, opt_state, batch):
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            params, opt_state, info = self.opt.update(grads, opt_state, params)
            return params, opt_state, {"loss": loss, **aux, **info}

        return step

    def update(self, batch: dict) -> dict:
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        for _ in range(self.cfg.epochs_per_batch):
            self.params, self.opt_state, metrics = self._step(
                self.params, self.opt_state, batch
            )
        metrics = jax.device_get(metrics)  # one transfer for all metrics
        return {k: float(v) for k, v in metrics.items()}

    # ------------------------------------------------------ rollout -> batch
    def make_batch(self, samples: list[dict], seq_len: int) -> dict:
        """samples: dicts with tokens (S,), actions (S,), action_mask (S,),
        rewards (S,) — padded/truncated to seq_len with GAE computed here."""
        B = len(samples)
        out = {
            k: np.zeros((B, seq_len), np.float32)
            for k in ("action_mask", "old_logp", "advantages", "returns")
        }
        out["tokens"] = np.zeros((B, seq_len), np.int32)
        out["actions"] = np.zeros((B, seq_len), np.int32)
        for i, s in enumerate(samples):
            T = min(len(s["tokens"]), seq_len)
            out["tokens"][i, :T] = s["tokens"][:T]
            out["actions"][i, :T] = s["actions"][:T]
            out["action_mask"][i, :T] = s["action_mask"][:T]
            out["old_logp"][i, :T] = s["old_logp"][:T]
            adv, ret = compute_gae(
                np.asarray(s["rewards"][:T], np.float32),
                np.asarray(s["values"][:T], np.float32),
                self.cfg.gamma,
                self.cfg.gae_lambda,
            )
            std = adv.std() + 1e-8
            out["advantages"][i, :T] = (adv - adv.mean()) / std
            out["returns"][i, :T] = ret
        return out

    def make_batch_columns(self, cols: dict, sel: np.ndarray, seq_len: int) -> dict:
        """Fused ``make_batch``: assemble an update batch straight from
        pre-stacked sample columns (``ReplayBuffer.sample_columns``) for
        the selected row indices — block copies plus one vectorized GAE
        sweep, no per-sample Python assembly.

        Bit-identical to running ``make_batch`` on the equivalent sample
        dicts: the advantage normalization still reduces over each row's
        live ``[:T]`` slice (``np.mean``/``np.std`` pairwise summation
        order is length-dependent, so a masked full-width reduction would
        round differently)."""
        sel = np.asarray(sel)
        B = len(sel)
        S_in = cols["tokens"].shape[1]
        W = min(S_in, seq_len)
        lengths = np.minimum(cols["length"][sel], W).astype(np.int64)
        live = np.arange(seq_len)[None, :] < lengths[:, None]
        out = {}
        for k, dt in (
            ("tokens", np.int32),
            ("actions", np.int32),
            ("action_mask", np.float32),
            ("old_logp", np.float32),
        ):
            buf = np.zeros((B, seq_len), dt)
            buf[:, :W] = cols[k][sel, :W]
            buf[~live] = 0  # guard rows wider than their recorded length
            out[k] = buf
        rewards = np.zeros((B, seq_len), np.float32)
        rewards[:, :W] = cols["rewards"][sel, :W]
        rewards[~live] = 0.0
        values = np.zeros((B, seq_len), np.float32)
        values[:, :W] = cols["values"][sel, :W]
        values[~live] = 0.0
        adv, ret = compute_gae_batch(rewards, values, self.cfg.gamma, self.cfg.gae_lambda)
        out["advantages"] = np.zeros((B, seq_len), np.float32)
        out["returns"] = np.zeros((B, seq_len), np.float32)
        for i in range(B):
            T = int(lengths[i])
            if T == 0:
                continue
            a = adv[i, :T]
            std = a.std() + 1e-8
            out["advantages"][i, :T] = (a - a.mean()) / std
            out["returns"][i, :T] = ret[i, :T]
        return out
