"""Semi-online asynchronous RL (§4.2 stage 3): rollout workers keep the OS
replicas busy through the data server's async batched interface while the
PPO learner samples decoupled batches from the replay buffer — rollouts and
updates run in parallel, exactly the paper's design.

    PYTHONPATH=src python examples/rl_ppo.py --updates 20
"""
import argparse
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core import (CowStore, DiskImage, DataServer, FaultInjector,
                        Gateway, RunnerPool)
from repro.core.tasks import TaskSuite
from repro.data import ReplayBuffer
from repro.data.tokenizer import ByteTokenizer, screenshot_tokens
from repro.models import build_model
from repro.train.ppo import PPOTrainer, PPOConfig


def rollout_worker(server, trainer, buffer, tok, cfg, stop, seq_len=48):
    """Continuously runs episodes; model chooses action tokens."""
    suite = TaskSuite(seed=7)
    rng = np.random.default_rng(0)
    while not stop.is_set():
        tasks = [t.to_dict() for t in suite.sample(4)]
        obs = server.reset(tasks)
        ctx = {o["slot"]: list(tok.encode("do task")) for o in obs}
        traj = {o["slot"]: {"tokens": [], "actions": [], "rewards": [],
                            "values": [], "old_logp": [], "action_mask": []}
                for o in obs}
        while server.live_slots() and not stop.is_set():
            live = server.live_slots()
            acts = {}
            for s in live:
                prefix = (ctx[s] + screenshot_tokens(
                    server.episode(s).obs, 4, cfg.vocab_size))[-seq_len:]
                toks = np.zeros(seq_len, np.int32)
                toks[:len(prefix)] = prefix
                logits, values = trainer.policy_value(
                    trainer.params, jnp.asarray(toks[None]))
                pos = len(prefix) - 1
                lp = jax.nn.log_softmax(logits[0, pos])
                a = int(rng.choice(cfg.vocab_size,
                                   p=np.exp(np.asarray(lp, np.float64))
                                   / np.exp(np.asarray(lp, np.float64)).sum()))
                t = traj[s]
                t["tokens"].append(toks[pos])
                t["actions"].append(a)
                t["old_logp"].append(float(lp[a]))
                t["values"].append(float(values[0, pos]))
                t["action_mask"].append(1.0)
                t["rewards"].append(0.0)
                acts[s] = f"action-{a}"
            server.step(acts)
        scores = server.evaluate()
        for s, sc in scores.items():
            if s in traj and traj[s]["rewards"]:
                traj[s]["rewards"][-1] = sc          # terminal reward
                buffer.add({k: np.asarray(v) for k, v in traj[s].items()})
        for s in list(scores):
            server.close_episode(s)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--updates", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = get_reduced("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trainer = PPOTrainer(model, params,
                         cfg=PPOConfig(lr=1e-5, batch_size=args.batch))

    store = CowStore(block_size=1 << 20)
    base = DiskImage.create_base(store, "ubuntu", 64 << 20)
    pools = [RunnerPool(f"n{i}", base, size=4,
                        faults=FaultInjector(seed=i), seed=i)
             for i in range(2)]
    server = DataServer(Gateway(pools), max_workers=8)
    buffer = ReplayBuffer(capacity=512)
    tok = ByteTokenizer()

    stop = threading.Event()
    worker = threading.Thread(
        target=rollout_worker,
        args=(server, trainer, buffer, tok, cfg, stop), daemon=True)
    worker.start()
    print("rollout worker started; learner samples asynchronously")

    done_updates = 0
    t0 = time.time()
    while done_updates < args.updates:
        if len(buffer) < 4:
            time.sleep(0.2)
            continue
        samples = buffer.sample(args.batch)
        batch = trainer.make_batch(samples, seq_len=48)
        metrics = trainer.update(batch)
        done_updates += 1
        if done_updates % 5 == 0:
            print(f"update {done_updates:3d} loss {metrics['loss']:.4f} "
                  f"entropy {metrics['entropy']:.3f} "
                  f"buffer={len(buffer)} (added {buffer.total_added})")
    stop.set()
    worker.join(timeout=10)
    server.close()
    print(f"{args.updates} PPO updates in {time.time()-t0:.1f}s; rollouts "
          f"and updates ran concurrently (semi-online asynchronous)")


if __name__ == "__main__":
    main()
