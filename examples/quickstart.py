"""Quickstart: spin up an OSGym fleet, run tasks through the single-entry
data server, and inspect the infrastructure metrics the paper reports.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (CowStore, DiskImage, DataServer, FaultInjector,
                        Gateway, RunnerPool)
from repro.core.tasks import TaskSuite

# 1. One 24 GB bootable base image; every replica reflink-clones it (§3.3).
store = CowStore()
base = DiskImage.create_base(store, "ubuntu-22.04", 24 * 10**9)
print(f"base image: {len(base.blocks)} blocks, "
      f"{store.physical_bytes()/1e9:.1f} GB physical")

# 2. Two executor nodes with pre-warmed runner pools (§3.4) behind a
#    task-affinity gateway, with stochastic software faults enabled.
pools = [RunnerPool(f"node{i}", base, size=8,
                    faults=FaultInjector(enabled=True, seed=i), seed=i)
         for i in range(2)]
gateway = Gateway(pools)

# 3. The centralized data server: one object, batched reset/step (§3.6).
server = DataServer(gateway, max_workers=16)
tasks = [t.to_dict() for t in TaskSuite(seed=0).sample(8)]
obs = server.reset(tasks)
print(f"started {len(obs)} episodes across "
      f"{len(gateway.healthy_nodes())} nodes")

# 4. Drive all episodes to completion; failures are retried/reassigned
#    transparently (§3.4 multi-layer recovery).
steps = 0
while server.live_slots():
    results = server.step({s: {"type": "click", "x": 100, "y": 200}
                           for s in server.live_slots()})
    steps += len(results)
scores = server.evaluate()

print(f"completed {len(scores)} episodes in {steps} env steps")
print(f"mean task score: {sum(scores.values())/len(scores):.3f}")
print("telemetry:", server.telemetry.snapshot()["counters"])
print(f"physical disk after run: {store.physical_bytes()/1e9:.2f} GB "
      f"(naive would be {(len(pools)*8+1)*24:.0f} GB)")
server.close()
