"""Elastic fleet demo: the autoscaler riding a task burst, live.

Builds two clusters over the same seeded burst workload — one static
fleet provisioned for the peak, one autoscaled fleet that starts small,
grows from gateway acquire-wait pressure during the burst (paying a
virtual boot delay), and drains afterwards — then prints what the
control plane did and what it cost in replica-days and USD.

    PYTHONPATH=src python examples/elastic_fleet.py --peak 64

Everything runs on the virtual-time event loop: the whole comparison is
a few wall-seconds, deterministic per seed.
"""
import argparse
import random
import time

from repro.cluster import AutoscalerConfig, Cluster, default_specs
from repro.core.event_loop import EventLoop
from repro.core.seeding import stable_seed
from repro.rollout.engine import RolloutConfig, RolloutEngine
from repro.rollout.scenarios import get_default_registry
from repro.rollout.writer import TrajectoryWriter


def burst_arrivals(n_burst: int, seed: int) -> list[float]:
    """Quiet start, hard Poisson burst at t=120vs, quiet tail."""
    rng = random.Random(stable_seed(seed, "demo-arrivals"))
    arrivals, t = [], 0.0
    for _ in range(max(n_burst // 10, 4)):
        t += rng.expovariate(0.2)
        arrivals.append(t)
    t = max(t, 120.0)
    for _ in range(n_burst):
        t += rng.expovariate(2.0)
        arrivals.append(t)
    return arrivals


def run(name: str, cluster: Cluster, arrivals, tasks) -> dict:
    writer = TrajectoryWriter(retain=False, capacity=2048)
    engine = RolloutEngine(cluster, writer,
                           config=RolloutConfig(max_inflight=len(tasks),
                                                acquire_timeout_vs=2000.0))
    report = engine.run_event_driven(tasks, loop=EventLoop(),
                                     arrivals=arrivals)
    waits = cluster.telemetry.summary("acquire_wait_vs")
    auto = cluster.autoscaler
    out = {
        "name": name,
        "completed": report.completed,
        "failed": report.failed,
        "makespan_vs": report.virtual_makespan,
        "peak_replicas": cluster.peak_placed,
        "replica_days": cluster.replica_days(),
        "p95_wait_vs": waits.get("p95", 0.0),
        "scale_ups": auto.scale_ups if auto else 0,
        "scale_downs": auto.scale_downs if auto else 0,
    }
    writer.close()
    cluster.close()
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--peak", type=int, default=64,
                    help="static fleet size / autoscaler ceiling")
    ap.add_argument("--start", type=int, default=8,
                    help="autoscaled fleet's starting size and floor")
    ap.add_argument("--burst-tasks", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    reg = get_default_registry()
    arrivals = burst_arrivals(args.burst_tasks, args.seed)
    tasks = reg.sample(len(arrivals), seed=stable_seed(args.seed, "demo"))
    print(f"workload: {len(tasks)} tasks, burst of {args.burst_tasks} "
          f"at t=120vs; fleets: static {args.peak} vs autoscaled "
          f"{args.start}->{args.peak}")

    t0 = time.time()
    static = run("static", Cluster(default_specs(args.peak), args.peak,
                                   seed=args.seed),
                 arrivals, tasks)
    scaler = AutoscalerConfig(min_replicas=args.start,
                              max_replicas=args.peak,
                              grow_step=max(args.peak // 4, 4))
    auto = run("autoscaled", Cluster(default_specs(args.peak), args.start,
                                     seed=args.seed, autoscaler=scaler),
               arrivals, tasks)

    for r in (static, auto):
        print(f"  {r['name']:>10}: {r['completed']} done "
              f"({r['failed']} failed), peak {r['peak_replicas']} "
              f"replicas, p95 wait {r['p95_wait_vs']:.1f}vs, "
              f"{r['replica_days']:.4f} replica-days, "
              f"scaled +{r['scale_ups']}/-{r['scale_downs']}")
    savings = 1.0 - auto["replica_days"] / static["replica_days"]
    assert auto["completed"] >= 0.95 * static["completed"]
    print(f"autoscaling spent {savings:.0%} fewer replica-days on the "
          f"same workload ({time.time() - t0:.1f}s wall for both fleets)")


if __name__ == "__main__":
    main()
