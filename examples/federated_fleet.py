"""Federated fleet demo: 2 regions, a brownout spill, one DiLoCo sync.

Two regions — cheap ``us`` and a pricier ``eu`` — serve one workload
through the ``repro.federation`` geo layer. Mid-run the ``eu`` region
goes dark (a full brownout: unreachable, every in-flight episode
killed); its homed episodes spill to ``us`` over metered WAN control
messages and their trajectories ship back home as WAN trajectory bytes.
The region is restored before the run ends, so late episodes route home
again. Afterwards each region's learner replica takes ``H`` inner PPO
steps on its own homed trajectories and the two exchange one DiLoCo
outer step — int8 parameter deltas over the same metered WAN — and the
demo prints the wire bytes next to what per-step delta streaming would
have cost.

    PYTHONPATH=src python examples/federated_fleet.py --replicas 24

Everything runs on the virtual-time event loop: the rollout half is
deterministic per seed and takes about a wall-second; the learner half
needs jax (CPU is fine).
"""
import argparse
import time

from repro.core.event_loop import EventLoop
from repro.core.seeding import stable_seed
from repro.federation import Federation, RegionSpec
from repro.rollout import (RolloutConfig, RolloutEngine, TrajectoryWriter,
                           get_default_registry)

TRAJS_PER_REGION = 12   # kept back for the learner half
SEQ_LEN = 64
DILOCO_H = 5            # inner steps before the one outer sync


def run_fleet(args, registry):
    """Rollout half: two regions, brownout + restore, spill accounting."""
    fed = Federation([
        RegionSpec("us", args.replicas, runners_per_node=8),
        RegionSpec("eu", args.replicas, runners_per_node=8,
                   price_multiplier=1.12),
    ], seed=args.seed)
    tele = fed.telemetry

    tasks = [t.to_dict() for t in registry.sample(
        args.tasks, seed=stable_seed(args.seed, "demo-workload"))]
    fed.assign(tasks)

    kept = {"us": [], "eu": []}
    writer = TrajectoryWriter(retain=False, capacity=4 * args.tasks)
    orig_write = writer.write

    def keeping_write(traj, timeout=None):
        lst = kept[fed.home_region(traj.task_id).name]
        if len(lst) < TRAJS_PER_REGION:
            lst.append(traj)
        return orig_write(traj, timeout)

    writer.write = keeping_write
    engine = RolloutEngine(fed, writer, registry=registry, telemetry=tele,
                           config=RolloutConfig(
                               max_inflight=2 * args.replicas,
                               acquire_timeout_vs=3000.0))
    loop = EventLoop()
    killed = []
    loop.call_later(args.brownout_at,
                    lambda: killed.append(fed.brownout("eu")), daemon=True)
    loop.call_later(args.restore_at, lambda: fed.restore("eu"), daemon=True)

    t0 = time.monotonic()
    report = engine.run_event_driven(tasks, loop=loop)
    wall = time.monotonic() - t0

    homed = {n: sum(1 for t in tasks if t["region"] == n)
             for n in ("us", "eu")}
    by_kind = fed.wan.bytes_by_kind()
    print(f"{len(tasks)} episodes over 2x{args.replicas} replicas -> "
          f"{report.completed} completed in {report.virtual_makespan:.0f} "
          f"virtual s ({wall:.1f}s wall)")
    print(f"brownout: eu dark at t={args.brownout_at:.0f}vs killed "
          f"{killed[0] if killed else 0} in-flight episodes; restored at "
          f"t={args.restore_at:.0f}vs")
    print(f"spill:    {tele.counter('episodes_spilled')} episodes ran out "
          f"of region ({tele.counter('wan_trajectories')} trajectories "
          f"shipped home over the WAN)")
    for pair, nbytes in sorted(fed.wan.ledger().items()):
        print(f"          {pair}: {nbytes / 1e6:.2f} MB on the wire")
    print(f"          by kind: "
          + ", ".join(f"{k}={v / 1e6:.2f} MB"
                      for k, v in sorted(by_kind.items())))
    assert report.completed > 0 and tele.counter("episodes_spilled") > 0
    writer.drain(timeout=10.0)
    writer.close()
    fed.close()
    return kept


def run_diloco(kept, registry, seed):
    """Learner half: H inner steps per region, one metered outer sync."""
    import jax

    from repro.configs import get_reduced
    from repro.core.telemetry import Telemetry
    from repro.data.replay_buffer import ReplayBuffer
    from repro.distributed.diloco import DiLoCoConfig
    from repro.federation import (FederatedLearners, RegionLearner,
                                  WanTopology)
    from repro.models import build_model
    from repro.pipeline import (IngestConfig, LearnerConfig,
                                PolicyVersionStore, TrajectoryIngestor)
    from repro.train.ppo import PPOConfig, PPOTrainer

    cfg = get_reduced("qwen3-1.7b", vocab_size=264, d_model=32, n_layers=1,
                      n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64)
    model = build_model(cfg)
    trainer = PPOTrainer(model, model.init(jax.random.PRNGKey(seed)),
                         cfg=PPOConfig(lr=3e-4), seed=seed)
    tele = Telemetry()
    wan = WanTopology.seeded(sorted(kept), seed=stable_seed(seed, "wan"),
                             telemetry=tele)
    learners = []
    for i, (name, trajs) in enumerate(sorted(kept.items())):
        replay = ReplayBuffer(capacity=128, seed=i, backend="soa",
                              seq_len=SEQ_LEN)
        store = PolicyVersionStore(trainer.params)
        ingest = TrajectoryIngestor(
            replay, store, registry=registry, trainer=trainer,
            cfg=IngestConfig(seq_len=SEQ_LEN, micro_batch=8))
        for t in trajs:
            ingest(t)
        ingest.flush()
        learners.append(RegionLearner(
            name, trainer, replay, store,
            cfg=LearnerConfig(batch_size=2, seq_len=SEQ_LEN)))
    plane = FederatedLearners(learners,
                              cfg=DiLoCoConfig(inner_steps=DILOCO_H),
                              wan=wan, telemetry=tele)

    for _ in range(DILOCO_H):
        for lr in learners:
            assert lr.step() is not None, f"{lr.name}: no batch ready"
    cost = plane.maybe_sync()
    assert cost is not None and plane.anchors_equal()

    diloco_bytes = tele.counter("wan_bytes_kind:diloco")
    stream_bytes = (plane.stream_bytes_per_region() * len(learners)
                    * DILOCO_H)
    print(f"\ndiloco:   {DILOCO_H} inner steps per region, then one outer "
          f"sync ({plane.n_params} params, int8 deltas)")
    for lr in learners:
        trend = lr.loss_trend()
        print(f"          {lr.name}: loss {trend['first_third']:.4f} -> "
              f"{trend['last_third']:.4f}")
    print(f"          {diloco_bytes / 1e3:.1f} KB on the WAN vs "
          f"{stream_bytes / 1e3:.1f} KB for per-step streaming "
          f"({stream_bytes / diloco_bytes:.0f}x fewer bytes); "
          f"post-sync anchors bit-identical across regions")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=24,
                    help="replicas per region")
    ap.add_argument("--tasks", type=int, default=96)
    ap.add_argument("--brownout-at", type=float, default=20.0,
                    help="virtual time of the eu brownout")
    ap.add_argument("--restore-at", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    registry = get_default_registry()
    kept = run_fleet(args, registry)
    run_diloco(kept, registry, args.seed)


if __name__ == "__main__":
    main()
