"""End-to-end SFT driver (§4.2 stage 2): collect demonstrations from the
OSGym fleet, pack them into interleaved (instruction, screenshot, thought,
action) sequences, and finetune an agent backbone for a few hundred steps
with fault-tolerant checkpointing.

Default: a reduced qwen3-family backbone that trains in minutes on CPU.
`--model-scale 100m` builds a ~100M-parameter config (the assignment's
end-to-end target; sized for a GPU/TPU host).

    PYTHONPATH=src python examples/train_sft.py --steps 300
"""
import argparse
import dataclasses

import jax

from repro.configs import get_reduced
from repro.data import ByteTokenizer, encode_trajectory, pack_batches, \
    synthetic_trajectories
from repro.distributed.checkpoint import CheckpointManager
from repro.models import build_model
from repro.train.optimizer import OptimizerConfig
from repro.train.sft import SFTTrainer


def build_cfg(scale: str):
    base = get_reduced("qwen3-1.7b")
    if scale == "smoke":
        return base
    if scale == "100m":
        return dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=32768)
    raise ValueError(scale)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--model-scale", default="smoke",
                    choices=["smoke", "100m"])
    ap.add_argument("--from-fleet", action="store_true",
                    help="collect live from the simulated fleet instead of "
                         "the synthetic offline set")
    args = ap.parse_args()

    cfg = build_cfg(args.model_scale)
    model = build_model(cfg)

    if args.from_fleet:
        import tests.test_system as helpers  # reuse the fleet collector
        trajs = helpers.collect_trajectories(n_tasks=16)
    else:
        trajs = synthetic_trajectories(128, seed=0)
    tok = ByteTokenizer()
    enc = [encode_trajectory(t, tok, cfg.vocab_size) for t in trajs]

    def stream():
        epoch = 0
        while True:
            yield from pack_batches(enc, batch=args.batch, seq_len=args.seq,
                                    seed=epoch)
            epoch += 1

    batches = stream()
    trainer = SFTTrainer(
        model, seed=0,
        opt_cfg=OptimizerConfig(lr=3e-4, warmup_steps=30,
                                decay_steps=args.steps))
    ckpt = CheckpointManager(keep=2)

    n = sum(p.size for p in jax.tree.leaves(trainer.params))
    print(f"training {n/1e6:.1f}M-param {cfg.family} backbone for "
          f"{args.steps} steps ({args.batch}x{args.seq} tokens/step)")
    losses = []
    for step in range(1, args.steps + 1):
        res = trainer.fit([next(batches)], verbose=False)
        losses.append(res.final_loss)
        if step % 25 == 0:
            print(f"step {step:4d}  loss {losses[-1]:.4f}")
        if step % 100 == 0:
            stats = ckpt.save(step, {"params": trainer.params})
            print(f"  checkpoint @{step}: +{stats['new_physical_bytes']/1e6:.1f} "
                  f"MB physical (block-dedup)")
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
