"""Mixed-fleet demo: four environment backends through one gateway, live.

One ``Cluster`` hosts a heterogeneous fleet — SimOS VMs, container-free
SWE sandboxes, headless browsers, and mobile emulators — each group
bin-packed onto hosts at its own RAM/CoW footprint. One ``Gateway``
serves a mixed episode stream with backend-constrained routing (a SWE
episode never lands on a browser pool), and the demo prints the
per-backend placement, completions, throughput, and the routing audit.

    PYTHONPATH=src python examples/mixed_fleet.py --per-backend 8

Everything runs on the virtual-time event loop: the whole run is about a
wall-second, deterministic per seed. See ``docs/ENVIRONMENTS.md`` for
the ``EnvBackend`` protocol and ``benchmarks/mixed_fleet.py`` for the
gated version with fault injection and the shared learner.
"""
import argparse
import time

from repro.cluster import Cluster, default_specs
from repro.core.event_loop import EventLoop
from repro.core.seeding import stable_seed
from repro.envs import backend_names, get_backend
from repro.rollout import RolloutConfig, RolloutEngine, TrajectoryWriter
from repro.rollout.scenarios import mixed_registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--per-backend", type=int, default=8,
                    help="replicas per backend")
    ap.add_argument("--episodes", type=int, default=3,
                    help="episodes per replica")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    backends = backend_names()
    n_total = args.per_backend * len(backends)
    registry = mixed_registry()

    print(f"== building a {n_total}-replica fleet, "
          f"{len(backends)} backends ==")
    for name in backends:
        b = get_backend(name)
        print(f"  {name:<8} {b.ram_limit_gb():>4.1f} GB/replica  "
              f"boot {b.latency().boot_s if b.latency() else 12.0:>5.1f} vs"
              f"  -- {b.description}")

    cluster = Cluster(
        default_specs(n_total, runners_per_node=args.per_backend),
        n_total, runners_per_node=args.per_backend, seed=args.seed,
        backends=[(name, args.per_backend) for name in backends])
    node_backend = {p.node_id: p.backend_name for p in cluster.pools}
    print("\nplacement (pools are single-backend):")
    for pool in cluster.pools:
        print(f"  {pool.node_id:<8} -> {pool.backend_name:<8} "
              f"({pool.size} runners)")

    tasks = []
    for name in backends:
        tasks.extend(registry.sample(
            args.per_backend * args.episodes,
            seed=stable_seed(args.seed, "demo", name), backends=[name]))

    writer = TrajectoryWriter(capacity=256, retain=False)
    engine = RolloutEngine(cluster, writer, registry=registry,
                           config=RolloutConfig(max_inflight=n_total,
                                                acquire_timeout_vs=1200.0))
    t0 = time.monotonic()
    report = engine.run_event_driven(tasks, loop=EventLoop())
    writer.drain(timeout=10.0)
    wall = time.monotonic() - t0

    completed = {name: 0 for name in backends}
    cross_routed = 0
    for r in report.results:
        want = r.task["backend"]
        cross_routed += sum(1 for node in r.nodes
                            if node_backend[node] != want)
        if r.ok:
            completed[want] += 1
    vmin = report.virtual_makespan / 60.0
    print(f"\n== {report.completed}/{len(tasks)} episodes in "
          f"{report.virtual_makespan:.0f} virtual s ({wall:.1f} wall s) ==")
    for name in backends:
        print(f"  {name:<8} {completed[name]:>4} completed  "
              f"{completed[name] / vmin:>6.1f} traj/min")
    print(f"routing audit: {cross_routed} episodes on a wrong-backend pool"
          + ("  <-- BUG" if cross_routed else "  (constrained routing holds)"))
    writer.close()
    cluster.close()


if __name__ == "__main__":
    main()
