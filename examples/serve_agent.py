"""Batched agent serving: a trained backbone answers batched action-decoding
requests through the ServeEngine (prefill + KV-cache decode) — the serving
counterpart of the dry-run's decode_32k cells.

    PYTHONPATH=src python examples/serve_agent.py --batch 8
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.data.tokenizer import ByteTokenizer, screenshot_tokens
from repro.models import build_model
from repro.serve import ServeEngine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_reduced("llava-next-mistral-7b")    # VLM-style agent backbone
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, seed=0)
    tok = ByteTokenizer()
    rng = np.random.default_rng(0)

    total_tok, total_s = 0, 0.0
    for r in range(args.rounds):
        prompts = []
        for b in range(args.batch):
            screen = rng.integers(0, 256, (48, 64, 3), dtype=np.uint8)
            ids = (tok.encode(f"req{r}-{b}: click the save button")
                   + screenshot_tokens(screen, 6, cfg.vocab_size))
            prompts.append(ids)
        L = max(len(p) for p in prompts)
        batch = np.zeros((args.batch, L), np.int32)
        for i, p in enumerate(prompts):
            batch[i, :len(p)] = p
        frames = rng.standard_normal(
            (args.batch, 8, cfg.frontend_dim)).astype(np.float32)
        t0 = time.time()
        out = engine.generate(batch, frames,
                              cfg=ServeConfig(max_new_tokens=args.max_new,
                                              temperature=0.7))
        dt = time.time() - t0
        n = args.batch * out["decode_steps"]
        total_tok += n
        total_s += dt
        print(f"round {r}: {args.batch} requests, prompt {L} tok, "
              f"{out['decode_steps']} decode steps, {n/dt:.1f} tok/s")
    print(f"aggregate decode throughput: {total_tok/total_s:.1f} tok/s "
          f"(batched, continuous slots)")


if __name__ == "__main__":
    main()
