"""Multi-tenant fleet demo: fair-share scheduling and admission control, live.

Three teams share one fleet through the ``repro.tenancy`` control plane:
a *gold* team with 4x weight and its own SLO, a *silver* team at 2x, and
a *bronze* batch team that fires a job spike through a tight token
bucket. The demo replays their merged seeded Poisson streams on the
event-driven engine and prints what the plane did: every admission
verdict class, the DRR service split, each tenant's submit->runner wait
tail, and the Jain fairness index.

    PYTHONPATH=src python examples/multitenant_fleet.py --replicas 24

Everything runs on the virtual-time event loop: the whole run is about a
wall-second, deterministic per seed.
"""
import argparse
import random
import time

from repro.cluster import Cluster, default_specs
from repro.core.event_loop import EventLoop
from repro.core.seeding import stable_seed
from repro.core.telemetry import p99
from repro.rollout import (RolloutConfig, RolloutEngine, TrajectoryWriter,
                           get_default_registry)
from repro.tenancy import FairShareScheduler, Tenant, jain_index


def build_stream(tenant_id, n_jobs, rate, seed, registry, start_vs=0.0):
    """One tenant's seeded Poisson submission stream."""
    rng = random.Random(stable_seed(seed, f"demo-{tenant_id}"))
    specs = registry.sample(n_jobs, seed=stable_seed(seed, f"tasks-{tenant_id}"))
    events, t = [], start_vs
    for spec in specs:
        t += rng.expovariate(rate)
        task = spec.to_dict()
        task["tenant"] = tenant_id
        events.append((t, task))
    return events


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=24)
    ap.add_argument("--jobs", type=int, default=40,
                    help="jobs per tenant (bronze sends 3x as a spike)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    tenants = [
        Tenant("gold", weight=4.0, slo_wait_p95_vs=60.0,
               burst_tokens=64.0, refill_per_vs=2.0),
        Tenant("silver", weight=2.0, slo_wait_p95_vs=120.0,
               burst_tokens=64.0, refill_per_vs=2.0),
        # the batch team: low weight and a tight bucket — its spike gets
        # throttled at the door instead of queueing behind everyone
        Tenant("bronze", weight=1.0, burst_tokens=16.0, refill_per_vs=0.2,
               max_queued=64),
    ]
    registry = get_default_registry()
    events = (
        build_stream("gold", args.jobs, 0.5, args.seed, registry)
        + build_stream("silver", args.jobs, 0.5, args.seed, registry)
        + build_stream("bronze", 3 * args.jobs, 4.0, args.seed, registry,
                       start_vs=30.0)
    )
    events.sort(key=lambda e: e[0])
    arrivals = [at for at, _ in events]
    tasks = [task for _, task in events]

    cluster = Cluster(default_specs(args.replicas), args.replicas,
                      runners_per_node=8, seed=args.seed)
    sched = FairShareScheduler(tenants, telemetry=cluster.telemetry)
    writer = TrajectoryWriter(retain=False, capacity=4096)
    engine = RolloutEngine(cluster, writer, registry=registry,
                           telemetry=cluster.telemetry,
                           config=RolloutConfig(
                               max_inflight=args.replicas,
                               acquire_timeout_vs=3000.0))

    t0 = time.monotonic()
    report = engine.run_event_driven(tasks, loop=EventLoop(),
                                     arrivals=arrivals, scheduler=sched)
    wall = time.monotonic() - t0

    print(f"{len(tasks)} jobs from {len(tenants)} tenants over "
          f"{args.replicas} replicas -> {report.completed} episodes in "
          f"{report.virtual_makespan:.0f} virtual s ({wall:.1f}s wall)\n")
    print(f"{'tenant':>8} {'weight':>6} {'sub':>5} {'adm':>5} {'thr':>5} "
          f"{'done':>5} {'share':>7} {'p99 wait':>9}")
    share = sched.share_of_fleet()
    for t in tenants:
        s = sched.stats()[t.tenant_id]
        print(f"{t.tenant_id:>8} {t.weight:>6.1f} {s.submitted:>5} "
              f"{s.admitted:>5} {s.throttled:>5} {s.completed:>5} "
              f"{share[t.tenant_id]:>6.1%} {p99(s.wait_vs):>8.1f}vs")

    quiet_done = [sched.stats()[t].completed for t in ("gold", "silver")]
    print(f"\nJain fairness (gold/silver): "
          f"{jain_index(quiet_done):.3f}")
    bronze = sched.stats()["bronze"]
    print(f"bronze spike: {bronze.throttled} of {bronze.submitted} "
          f"submissions throttled at the door (explicit verdicts — "
          f"no silent queue growth)")
    assert report.completed > 0 and bronze.throttled > 0

    writer.drain(timeout=10.0)
    writer.close()
    cluster.close()


if __name__ == "__main__":
    main()
