"""Parallel demonstration collection (§4.2 stage 1) on the rollout engine.

Scenario-diverse multi-turn episodes run concurrently through
``RolloutEngine`` (bounded in-flight scheduling, failover on faults) over
the gateway/runner-pool stack; the ``TrajectoryWriter`` streams every
completed episode — encoded for SFT — into the replay buffer, and the
example finishes by packing a training batch from it, proving the full
collect → encode → buffer → batch path. Real threaded execution at laptop
scale + the 1024-replica virtual-time projection the paper reports.

    PYTHONPATH=src python examples/collect_trajectories.py --tasks 16

``--event-driven`` runs the same episodes as cooperative tasks on the
virtual-time event loop instead of threads — the mode that scales to
paper-size fleets (see benchmarks/throughput.py).
"""
import argparse
from collections import Counter

from repro.core import (CowStore, DiskImage, FaultInjector, Gateway,
                        RunnerPool)
from repro.data import ByteTokenizer
from repro.data.pipeline import pack_batches
from repro.data.replay_buffer import ReplayBuffer
from repro.rollout import (RolloutConfig, RolloutEngine, TrajectoryWriter,
                           get_default_registry)

VOCAB = 151936


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=16)
    ap.add_argument("--max-inflight", type=int, default=12)
    ap.add_argument("--event-driven", action="store_true",
                    help="run episodes on the virtual-time event loop "
                         "instead of threads (the paper-scale mode)")
    args = ap.parse_args()

    store = CowStore()
    base = DiskImage.create_base(store, "ubuntu", 24 * 10**9)
    pools = [RunnerPool(f"node{i}", base, size=args.replicas // 2,
                        faults=FaultInjector(enabled=True, seed=i), seed=i)
             for i in range(2)]
    gateway = Gateway(pools)

    registry = get_default_registry()
    replay = ReplayBuffer(capacity=4096)
    writer = TrajectoryWriter(replay=replay, tokenizer=ByteTokenizer(),
                              vocab_size=VOCAB, capacity=128)
    engine = RolloutEngine(
        gateway, writer, registry=registry,
        config=RolloutConfig(max_inflight=args.max_inflight))

    tasks = registry.sample(args.tasks, seed=0)
    report = (engine.run_event_driven(tasks) if args.event_driven
              else engine.run(tasks))
    writer.drain()

    families = Counter(registry.resolve(r.task).family
                       for r in report.results if r.ok)
    print(f"collected {report.completed} trajectories "
          f"({report.failed} failed) / {report.total_steps} steps / "
          f"{writer.stats.encoded_tokens} tokens "
          f"in {report.wall_seconds:.1f}s wall")
    print(f"scenario mix: {dict(families)}")
    print(f"fault recovery: {report.reassignments} reassignments, "
          f"peak in-flight {report.peak_inflight} "
          f"(bound {args.max_inflight}), "
          f"{report.backpressure_waits} backpressure waits")
    vs = report.virtual_seconds
    print(f"virtual env time: {vs:,.0f}s "
          f"({vs / max(report.total_steps, 1):.1f}s/step — paper: ~2s/step)")
    print(f"projected 1024-replica rate: "
          f"{report.trajectories_per_min(1024):,.0f} trajectories/min "
          f"(paper: ~1420)")

    # prove the SFT/PPO consumption path: replay buffer -> packed batch
    sample = replay.sample(min(8, len(replay)))
    encoded = [(item["tokens"], item["loss_mask"]) for item in sample]
    batch = next(pack_batches(encoded, batch=2, seq_len=512), None)
    if batch is not None:
        print(f"packed training batch: tokens {batch['tokens'].shape}, "
              f"loss on {batch['mask'].mean():.0%} of targets")
    print(f"replay buffer: {len(replay)} items "
          f"({replay.total_added} added total)")
    print("telemetry:", engine.telemetry.snapshot()["counters"])

    writer.close()
    gateway.stop()
    for p in pools:
        p.close()


if __name__ == "__main__":
    main()
