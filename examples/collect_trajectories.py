"""Parallel demonstration collection (§4.2 stage 1): agents drive OS replicas
through the data server; trajectories (screenshot/thought/action) are encoded
for SFT. Real threaded execution at laptop scale + the 1024-replica
virtual-time projection the paper reports.

    PYTHONPATH=src python examples/collect_trajectories.py --tasks 12
"""
import argparse
import time

from repro.core import (CowStore, DiskImage, DataServer, FaultInjector,
                        Gateway, RunnerPool)
from repro.core.replica import LatencyModel
from repro.core.tasks import TaskSuite, TABLE3_ROWS
from repro.data import Trajectory, TrajectoryStep, ByteTokenizer, \
    encode_trajectory


def scripted_agent(obs, step_idx):
    """Stand-in for UI-TARS / Agent-S: deterministic scripted policy."""
    actions = ["click(120, 84)", "type('quarterly report')", "scroll(-2)",
               "key('ctrl+s')", "drag(40, 40, 200, 90)"]
    thought = f"The screen shows state {obs.sum() % 997}; next I will act."
    return thought, actions[step_idx % len(actions)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=12)
    ap.add_argument("--replicas", type=int, default=16)
    args = ap.parse_args()

    store = CowStore()
    base = DiskImage.create_base(store, "ubuntu", 24 * 10**9)
    pools = [RunnerPool(f"node{i}", base, size=args.replicas // 2,
                        faults=FaultInjector(enabled=True, seed=i), seed=i)
             for i in range(2)]
    server = DataServer(Gateway(pools), max_workers=args.replicas)
    tasks = [t.to_dict() for t in TaskSuite(seed=0).sample(args.tasks)]

    t0 = time.time()
    obs0 = server.reset(tasks)
    trajs: dict[int, list] = {o["slot"]: [] for o in obs0}
    last_obs = {o["slot"]: o["obs"] for o in obs0}
    virtual_s = 0.0
    it = 0
    while server.live_slots():
        pending = {}
        for s in server.live_slots():
            pending[s] = scripted_agent(last_obs[s], it)
        results = server.step({s: a for s, (_, a) in pending.items()})
        for s, (obs, rew, done, info) in results.items():
            thought, action = pending[s]
            trajs[s].append(TrajectoryStep(obs, thought, action))
            last_obs[s] = obs
        it += 1
    scores = server.evaluate()
    wall = time.time() - t0
    for ep in list(trajs):
        virtual_s += server.episode(ep).virtual_seconds

    out = [Trajectory(t["task_id"], t["description"], steps,
                      scores.get(slot, 0.0))
           for (slot, steps), t in zip(trajs.items(), tasks)]
    tok = ByteTokenizer()
    enc = [encode_trajectory(t, tok, 151936) for t in out]
    n_steps = sum(len(t.steps) for t in out)
    n_tokens = sum(len(ids) for ids, _ in enc)

    print(f"collected {len(out)} trajectories / {n_steps} steps / "
          f"{n_tokens} tokens in {wall:.1f}s wall")
    print(f"virtual env time: {virtual_s:,.0f}s "
          f"({virtual_s / max(n_steps,1):.1f}s/step — paper: ~2s/step)")
    rate_1024 = 1024 * 60 / (virtual_s / max(len(out), 1))
    print(f"projected 1024-replica rate: {rate_1024:,.0f} trajectories/min "
          f"(paper: ~1420)")
    print("telemetry:", server.telemetry.snapshot()["counters"])
    server.close()


if __name__ == "__main__":
    main()
