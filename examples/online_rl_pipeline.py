"""End-to-end online RL: fleet rollouts feeding the PPO learner, live.

The full actor/learner split from ``repro.pipeline`` in its concurrent
mode: an actor thread streams event-driven rollout rounds over a faulted
fleet while the PPO learner (reduced ``qwen3-1.7b``, jitted JAX) updates
from the replay buffer as experience lands. Scenario outcomes are shaped
into rewards per task family, every sample is stamped with its
behavior-policy version, and off-policy experience beyond the staleness
bound is reweighted (or dropped) — the counters printed at the end show
the staleness the async split actually produced.

    PYTHONPATH=src python examples/online_rl_pipeline.py --updates 12

``--interleaved`` runs the deterministic alternating mode (the benchmark
and CI configuration) instead of the concurrent split.
"""
import argparse
import time

import jax

from repro.configs import get_reduced
from repro.models import build_model
from repro.pipeline import (IngestConfig, LearnerConfig, OnlinePipeline,
                            PipelineConfig, build_fleet)
from repro.train.ppo import PPOConfig, PPOTrainer
from repro.train.sft import SFTTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--updates", type=int, default=12)
    ap.add_argument("--replicas", type=int, default=16)
    ap.add_argument("--tasks-per-round", type=int, default=12)
    ap.add_argument("--algo", choices=("ppo", "sft"), default="ppo")
    ap.add_argument("--staleness-bound", type=int, default=4)
    ap.add_argument("--staleness-policy", default="reweight",
                    choices=("reweight", "drop"))
    ap.add_argument("--interleaved", action="store_true",
                    help="deterministic alternating mode instead of the "
                         "concurrent actor/learner split")
    args = ap.parse_args()

    t0 = time.time()
    cfg = get_reduced("qwen3-1.7b", vocab_size=264)
    model = build_model(cfg)
    if args.algo == "ppo":
        params = model.init(jax.random.PRNGKey(0))
        trainer = PPOTrainer(model, params, cfg=PPOConfig(lr=3e-4))
    else:
        trainer = SFTTrainer(model, seed=0)
    cluster = build_fleet(args.replicas, seed=0)
    rounds = max(args.updates // 4, 2)
    pipe = OnlinePipeline(
        cluster, args.replicas, trainer,
        pipe_cfg=PipelineConfig(rounds=rounds,
                                tasks_per_round=args.tasks_per_round,
                                updates_per_round=4,
                                max_inflight=args.replicas),
        learner_cfg=LearnerConfig(algo=args.algo, batch_size=8,
                                  seq_len=192,
                                  staleness_bound=args.staleness_bound,
                                  staleness_policy=args.staleness_policy),
        ingest_cfg=IngestConfig(seq_len=192))
    print(f"fleet: {args.replicas} replicas; learner: {args.algo} on "
          f"reduced qwen3-1.7b; mode: "
          f"{'interleaved' if args.interleaved else 'concurrent'}")
    try:
        if args.interleaved:
            report = pipe.run_interleaved()
        else:
            report = pipe.run_concurrent(total_updates=args.updates)
    finally:
        pipe.close()
        cluster.close()

    lat = report.rollout_to_learner_s
    print(f"rollouts: {report.rollout_completed} trajectories "
          f"({report.rollout_failed} failed, "
          f"{report.reassignments} fault reassignments) — "
          f"{report.rollout_traj_per_min:.1f} traj/min virtual")
    print(f"learner: {report.updates} updates "
          f"({report.learner_steps_per_min:.1f} steps/min), "
          f"{report.versions_published} policy versions published")
    print(f"loss: {report.loss_first_third:.4f} -> "
          f"{report.loss_last_third:.4f} "
          f"(decreased={report.loss_decreased})")
    print(f"staleness (bound {args.staleness_bound}, "
          f"{args.staleness_policy}): {report.stale_reweighted} reweighted, "
          f"{report.stale_dropped} dropped; mean sample staleness "
          f"{report.staleness.get('mean', 0):.1f} versions")
    print(f"rollout->learner latency: p50 {lat.get('p50', 0):.2f}s "
          f"p95 {lat.get('p95', 0):.2f}s")
    print(f"success rate {report.success_rate:.0%} across "
          f"{len(report.success_by_family)} scenario families; "
          f"wall {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
